"""Binary wire protocol + front-door behaviors (PR-8 serving tier).

The load-bearing guarantees:
  * the binary and JSON wires produce *byte-identical* prediction
    payloads against one server (canonical JSON, trace ids aside);
  * malformed / truncated binary frames come back as structured errors
    without killing the server (recoverable ones keep the connection);
  * the generic tag codec and the specialized predict_batch codecs are
    exact round trips on randomized values and blocks;
  * overload sheds typed ``Overloaded`` errors through a bounded queue;
  * client timeouts / resets surface as typed ``ServiceUnavailable``
    after a bounded retry budget;
  * the access log rotates by size, the sharded cache keeps legacy
    aggregate stats, and the exact-request wave cache revalidates on
    model reload.
"""
import json
import os
import random
import socket
import struct
import threading
import time

import pytest

from repro.core import model_io
from repro.core.engine import Campaign
from repro.core.isa import TEST_ISA
from repro.core.predictor import predict
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_SKL
from repro.obs.metrics import Histogram
from repro.service import protocol
from repro.service.client import (ServiceClient, ServiceOverloaded,
                                  ServiceUnavailable)
from repro.service.protocol import prediction_to_dict
from repro.service.registry import ModelRegistry
from repro.service.server import (AdmissionController, PredictionServer,
                                  PredictionService, ShardedLRU,
                                  ThreadedPredictionServer)
from repro.service.workload import random_blocks

NAMES = ["ADD_R64_R64", "IMUL_R64_R64", "MUL_R64", "CMC", "TEST_R64_R64",
         "AESDEC_X_X", "PSHUFD_X_X", "MOV_R64_M64"]


@pytest.fixture(scope="module")
def skl_model():
    machine = SimMachine(SIM_SKL, TEST_ISA)
    return Campaign(instr_names=NAMES).run([machine],
                                           TEST_ISA).models[machine.name]


@pytest.fixture(scope="module")
def model_dir(skl_model, tmp_path_factory):
    out = tmp_path_factory.mktemp("models")
    (out / "sim_skl.xml").write_text(model_io.to_xml(skl_model, TEST_ISA))
    return out


def _canon(envs):
    return json.dumps([{k: v for k, v in e.items() if k != "trace_id"}
                       for e in envs], sort_keys=True)


# ---------------------------------------------------------------------------
# codecs: tag values and the specialized predict_batch frames
# ---------------------------------------------------------------------------


def _random_value(rng, depth=0):
    kinds = ["none", "bool", "int", "float", "str", "bytes"]
    if depth < 3:
        kinds += ["list", "dict"]
    k = rng.choice(kinds)
    if k == "none":
        return None
    if k == "bool":
        return rng.random() < 0.5
    if k == "int":
        return rng.choice([0, 1, -1, rng.randrange(-2**40, 2**40),
                           2**63 - 1, -2**63])
    if k == "float":
        return rng.choice([0.0, -0.0, 1.5, float("inf"),
                           rng.uniform(-1e12, 1e12)])
    if k == "str":
        return "".join(rng.choice("abπ∞\n\"\\x") for _ in range(
            rng.randrange(8)))
    if k == "bytes":
        return bytes(rng.randrange(256) for _ in range(rng.randrange(8)))
    if k == "list":
        return [_random_value(rng, depth + 1) for _ in range(rng.randrange(4))]
    return {f"k{j}": _random_value(rng, depth + 1)
            for j in range(rng.randrange(4))}


def test_value_codec_roundtrip_seeded():
    rng = random.Random(7)
    for _ in range(300):
        v = _random_value(rng)
        assert protocol.unpack_value(protocol.pack_value(v)) == v


def test_value_codec_roundtrip_hypothesis():
    """Property-based variant when hypothesis is installed (the seeded
    fuzz above always runs)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    values = st.recursive(
        st.none() | st.booleans() | st.integers(-2**63, 2**63 - 1)
        | st.floats(allow_nan=False) | st.text(max_size=16)
        | st.binary(max_size=16),
        lambda c: st.lists(c, max_size=4)
        | st.dictionaries(st.text(max_size=8), c, max_size=4),
        max_leaves=12)

    @hyp.settings(max_examples=100, deadline=None)
    @hyp.given(values)
    def inner(v):
        assert protocol.unpack_value(protocol.pack_value(v)) == v

    inner()


def test_predict_batch_request_roundtrip(skl_model):
    rng = random.Random(13)
    for trial in range(20):
        blocks = random_blocks(skl_model, TEST_ISA, rng.randrange(1, 12),
                               seed=trial)
        packed = tuple(protocol.instrs_to_packed(b) for b in blocks)
        budget = rng.choice([0, 1, 2500, 10**7])
        payload = protocol.encode_predict_batch("sim_skl", packed, budget)
        ua, got_budget, got = protocol.decode_predict_batch(payload)
        assert (ua, got_budget, got) == ("sim_skl", budget, packed)
        # packed form is lossless back to Instr objects
        for b, pb in zip(blocks, packed):
            assert protocol.packed_to_instrs(pb) == b


def test_response_codec_preserves_envelope_shapes(skl_model):
    blocks = random_blocks(skl_model, TEST_ISA, 6, seed=3)
    preds = [predict(skl_model, TEST_ISA, b) for b in blocks]
    envs = [{"ok": True, "uarch": "sim_skl",
             "result": prediction_to_dict(p)} for p in preds]
    err = {"ok": False, "error": {"type": "UnknownInstructionError",
                                  "message": "nope", "missing": ["X"]}}
    port_names = sorted({p for e in envs
                         for p in e["result"]["port_pressure"]})
    pidx = {p: i for i, p in enumerate(port_names)}
    chunks = [protocol.encode_pred_chunk(e, pidx) for e in envs]
    chunks.append(protocol.encode_error_chunk(err))
    payload = protocol.encode_predict_batch_resp("a" * 16, "sim_skl",
                                                 port_names, chunks)
    out = protocol.decode_predict_batch_resp(payload)
    assert len(out) == len(envs) + 1
    for e, got in zip(envs, out):
        assert got == {**e, "trace_id": "a" * 16}
    # the error envelope gains only trace_id — no phantom "uarch" key
    assert out[-1] == {**err, "trace_id": "a" * 16}


def test_read_frame_rejects_garbage():
    import io

    with pytest.raises(protocol.BinaryProtocolError):
        protocol.read_frame(io.BytesIO(b"\x00\x01\x00\x00\x00\x00"))
    oversize = struct.pack(">BBI", protocol.BINARY_MAGIC, protocol.K_MSG,
                           protocol.MAX_FRAME + 1)
    with pytest.raises(protocol.BinaryProtocolError):
        protocol.read_frame(io.BytesIO(oversize))
    # truncated mid-frame: a ConnectionError, not silence
    good = protocol.frame(protocol.K_MSG, b"x" * 32)
    with pytest.raises(ConnectionError):
        protocol.read_frame(io.BytesIO(good[:10]))
    assert protocol.read_frame(io.BytesIO(b"")) is None  # clean EOF


# ---------------------------------------------------------------------------
# negotiation + payload identity
# ---------------------------------------------------------------------------


def test_both_wires_byte_identical_payloads(model_dir, skl_model):
    blocks = random_blocks(skl_model, TEST_ISA, 40, seed=29)
    ref = _canon([{"ok": True, "uarch": "sim_skl",
                   "result": prediction_to_dict(
                       predict(skl_model, TEST_ISA, b))} for b in blocks])
    svc = PredictionService(ModelRegistry(model_dir))
    with PredictionServer(svc) as server:
        with ServiceClient(server.host, server.port, wire="json") as cj, \
                ServiceClient(server.host, server.port, wire="auto") as cb:
            assert cj.wire == "json"
            assert cb.wire == "binary"  # auto negotiates binary here
            for _ in range(3):  # cold, warm (cached segments), wave-cache
                assert _canon(cj.predict_batch("sim_skl", blocks)) == ref
                assert _canon(cb.predict_batch("sim_skl", blocks)) == ref
        assert svc.wave_cache.stats()["hits"] >= 1
        st = svc.stats()
        assert st["wire"]["binary_conns"] >= 1
        assert st["wire"]["json_conns"] >= 1
        assert "wave_cache" in st and "admission" in st


def test_auto_falls_back_to_json_on_legacy_server(model_dir, skl_model):
    blocks = random_blocks(skl_model, TEST_ISA, 8, seed=31)
    with ThreadedPredictionServer(
            PredictionService(ModelRegistry(model_dir))) as server:
        with ServiceClient(server.host, server.port, wire="auto") as c:
            assert c.wire == "json"
            envs = c.predict_batch("sim_skl", blocks)
            assert all(e["ok"] for e in envs)
        with pytest.raises(ServiceUnavailable):
            ServiceClient(server.host, server.port, wire="binary")


def test_wave_cache_revalidates_on_reload(model_dir, skl_model):
    blocks = random_blocks(skl_model, TEST_ISA, 6, seed=37)
    svc = PredictionService(ModelRegistry(model_dir))
    with PredictionServer(svc) as server:
        with ServiceClient(server.host, server.port, wire="binary") as c:
            first = _canon(c.predict_batch("sim_skl", blocks))
            assert _canon(c.predict_batch("sim_skl", blocks)) == first
            hits = svc.wave_cache.stats()["hits"]
            assert hits >= 1
            # rewrite the artifact (same content, new mtime): version bumps
            path = model_dir / "sim_skl.xml"
            st = path.stat()
            path.write_text(model_io.to_xml(skl_model, TEST_ISA))
            os.utime(path, ns=(st.st_mtime_ns + 10**9,
                               st.st_mtime_ns + 10**9))
            c.reload("sim_skl")
            # stale wave entry is rejected by its version, then recomputed
            assert _canon(c.predict_batch("sim_skl", blocks)) == first
            assert _canon(c.predict_batch("sim_skl", blocks)) == first


# ---------------------------------------------------------------------------
# malformed frames
# ---------------------------------------------------------------------------


def _binary_conn(server):
    sock = socket.create_connection((server.host, server.port), timeout=10)
    rfile = sock.makefile("rb")
    sock.sendall(protocol.hello_frame())
    kind, payload = protocol.read_frame(rfile)
    assert kind == protocol.K_HELLO_ACK
    return sock, rfile


def test_malformed_frames_keep_connection(model_dir):
    with PredictionServer(
            PredictionService(ModelRegistry(model_dir))) as server:
        sock, rfile = _binary_conn(server)
        # garbage payload in a known kind: structured error, conn lives
        sock.sendall(protocol.frame(protocol.K_PREDICT_BATCH, b"\xff\xff"))
        kind, payload = protocol.read_frame(rfile)
        env = protocol.unpack_value(payload)
        assert env["ok"] is False
        assert env["error"]["type"] == "BinaryProtocolError"
        # unknown frame kind: structured error, conn lives
        sock.sendall(protocol.frame(200, b""))
        kind, payload = protocol.read_frame(rfile)
        assert protocol.unpack_value(payload)["error"]["type"] == \
            "BinaryProtocolError"
        # the same connection still serves good requests
        sock.sendall(protocol.frame(
            protocol.K_MSG, protocol.pack_value({"op": "ping"})))
        kind, payload = protocol.read_frame(rfile)
        pong = protocol.unpack_value(payload)
        assert pong["ok"] is True and pong["result"] == "pong"
        sock.close()
        assert server.wire_counts["bad_frames"] >= 2


def test_frame_desync_errors_and_closes(model_dir):
    with PredictionServer(
            PredictionService(ModelRegistry(model_dir))) as server:
        sock, rfile = _binary_conn(server)
        sock.sendall(b"\x00garbage-without-magic")
        kind, payload = protocol.read_frame(rfile)
        env = protocol.unpack_value(payload)
        assert env["ok"] is False
        assert "desync" in env["error"]["message"]
        assert rfile.read(1) == b""  # server closed: cannot resync
        sock.close()
        # a truncated frame (EOF mid-payload) must not wedge the server
        sock2 = socket.create_connection((server.host, server.port),
                                         timeout=10)
        sock2.sendall(protocol.hello_frame()[:3])
        sock2.close()
        with ServiceClient(server.host, server.port) as c:
            assert c.ping()


def test_unsupported_binary_version_is_rejected(model_dir):
    with PredictionServer(
            PredictionService(ModelRegistry(model_dir))) as server:
        sock = socket.create_connection((server.host, server.port),
                                        timeout=10)
        rfile = sock.makefile("rb")
        sock.sendall(protocol.frame(protocol.K_HELLO, bytes([99]) + b"\n"))
        kind, payload = protocol.read_frame(rfile)
        env = protocol.unpack_value(payload)
        assert env["ok"] is False
        assert "version" in env["error"]["message"]
        sock.close()


# ---------------------------------------------------------------------------
# admission control / shedding
# ---------------------------------------------------------------------------


def test_admission_controller_sheds_bounded():
    ac = AdmissionController(workers=1, max_queue=0)
    assert ac.try_admit() is None
    assert ac.try_admit() == "queue_full"  # queue bound is hard
    env = ac.overloaded_env("queue_full")
    assert env["error"]["type"] == "Overloaded"
    assert env["error"]["reason"] == "queue_full"
    assert env["error"]["retry_after_ms"] > 0
    ac.release(0.002)
    assert ac.try_admit() is None
    st = ac.stats()
    assert st["shed_queue_full"] == 1 and st["admitted"] == 2
    assert st["peak_inflight"] <= st["workers"] + st["max_queue"]
    # budget-based shed: estimated sojourn exceeds the request budget
    ac2 = AdmissionController(workers=1, max_queue=10, budget_us=1.0)
    assert ac2.try_admit() is None
    assert ac2.try_admit() is None       # first queued slot is free
    assert ac2.try_admit() == "budget"   # (q+1)*ewma blows the 1us budget
    assert ac2.stats()["shed_budget"] == 1


def test_server_sheds_typed_overloaded(model_dir, skl_model):
    svc = PredictionService(ModelRegistry(model_dir))
    with PredictionServer(svc, workers=1, max_queue=0) as server:
        shed = threading.Semaphore(0)
        errors = []

        def hammer(seed):
            rng = random.Random(seed)
            try:
                with ServiceClient(server.host, server.port,
                                   wire="json") as c:
                    for i in range(12):
                        blocks = [[Instr("IMUL_R64_R64",
                                         {"op1": f"R{rng.randrange(16)}",
                                          "op2": f"R{i}"})]
                                  for _ in range(16)]
                        try:
                            c.predict_batch("sim_skl", blocks)
                        except ServiceOverloaded as e:
                            assert e.error["reason"] == "queue_full"
                            assert e.error["retry_after_ms"] >= 0
                            shed.release()
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(s,))
                   for s in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        adm = server.admission.stats()
        assert shed.acquire(blocking=False), adm
        assert adm["shed"] > 0
        assert adm["peak_inflight"] <= adm["workers"] + adm["max_queue"]
        # the server still answers normally after the storm
        with ServiceClient(server.host, server.port) as c:
            assert c.ping()
            assert svc.stats()["admission"]["shed"] > 0


# ---------------------------------------------------------------------------
# client robustness
# ---------------------------------------------------------------------------


def test_connect_failure_is_service_unavailable():
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()  # nothing listens here any more
    t0 = time.perf_counter()
    with pytest.raises(ServiceUnavailable):
        ServiceClient("127.0.0.1", port, timeout=2, retries=2,
                      backoff_s=0.01)
    assert time.perf_counter() - t0 < 10


def test_read_timeout_is_service_unavailable():
    silent = socket.socket()
    silent.bind(("127.0.0.1", 0))
    silent.listen(4)
    port = silent.getsockname()[1]
    accepted = []

    def accept_loop():
        try:
            while True:
                conn, _ = silent.accept()
                accepted.append(conn)  # accept, then say nothing
        except OSError:
            pass

    t = threading.Thread(target=accept_loop, daemon=True)
    t.start()
    try:
        client = ServiceClient("127.0.0.1", port, timeout=0.3, wire="json",
                               retries=0)
        with pytest.raises(ServiceUnavailable):
            client.ping()
        client.close()
    finally:
        silent.close()
        for c in accepted:
            c.close()


# ---------------------------------------------------------------------------
# access-log rotation, sharded cache, histogram bulk observe
# ---------------------------------------------------------------------------


def test_access_log_rotates_by_size(model_dir, tmp_path):
    log = tmp_path / "access.log"
    svc = PredictionService(ModelRegistry(model_dir), access_log=str(log),
                            access_log_max_bytes=400)
    for i in range(12):
        svc.predict("sim_skl", [Instr("CMC", {})])
    svc.close()
    rolled = tmp_path / "access.log.1"
    assert rolled.exists()
    assert rolled.stat().st_size >= 400
    # the current file restarts small (it may not exist yet if the very
    # last write was the one that rotated)
    assert not log.exists() or log.stat().st_size < 400 + 300
    for line in rolled.read_text().splitlines():
        rec = json.loads(line)
        assert rec["endpoint"] == "predict"


def test_sharded_lru_semantics():
    lru = ShardedLRU(capacity=16, shards=4)
    for i in range(40):
        lru.put(("k", i), i)
    assert len(lru) <= 16 + 3  # per-shard ceil rounding
    got = lru.get_many([("k", i) for i in range(40)])
    assert sum(1 for g in got if g is not None) == len(lru)
    st = lru.stats()
    assert {"size", "capacity", "hits", "misses", "hit_rate"} <= set(st)
    assert len(st["shards"]) == 4
    assert sum(s["hits"] for s in st["shards"]) == st["hits"]
    assert lru.get(("missing",)) is None


def test_histogram_observe_many_matches_loop():
    a, b = Histogram("a"), Histogram("b")
    for v, n in ((1.5, 3), (0.25, 5), (9.0, 1)):
        a.observe_many(v, n)
        for _ in range(n):
            b.observe(v)
    assert a.snapshot() == b.snapshot()

"""The IACA-analogue predictor and the legacy (IACA-with-bugs) analyzer."""
import pytest

from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq, measure
from repro.core.predictor import LegacyAnalyzer, predict
from repro.core.simulator import Instr


def test_port_bound_dominates_independent_alu(skl_model):
    code = [Instr("IMUL_R64_R64", {"op1": f"R{i}", "op2": f"R{i + 8}"})
            for i in range(3)]
    p = predict(skl_model, TEST_ISA, code)
    assert p.port_bound == pytest.approx(3.0)  # 3 μops, only p1
    assert p.bottleneck == "ports"


def test_latency_bound_dominates_chain(skl_model):
    p = predict(skl_model, TEST_ISA,
                [Instr("IMUL_R64_R64", {"op1": "R0", "op2": "R1"})])
    assert p.latency_bound == pytest.approx(3.0)
    assert p.cycles == pytest.approx(3.0)


def test_frontend_bound(skl_model):
    # 8 independent 1-μop ALU ops over 4 ports: ports=2.0, frontend=2.0
    code = [Instr("ADD_R64_R64", {"op1": f"R{i}", "op2": f"R{i + 8}"})
            for i in range(8)]
    p = predict(skl_model, TEST_ISA, code)
    assert p.cycles == pytest.approx(2.0)


def test_per_pair_latency_pays_off_aesdec(snb_machine):
    """Chain through AESDEC's *second* operand (the round key), with the
    state register freshly broken each iteration (e.g. a counter-mode-style
    kernel): the per-pair model predicts ~2 cycles/iter; a scalar-latency
    model (legacy/IACA) predicts >= 8 — §7.3.1's practical consequence."""
    from repro.core.characterize import characterize

    model = characterize(snb_machine, TEST_ISA,
                         ["AESDEC_X_X", "PSHUFD_X_X", "PCMPGTQ_X_X"])
    code = [Instr("PCMPGTQ_X_X", {"op1": "X0", "op2": "X0"}),  # break state
            Instr("AESDEC_X_X", {"op1": "X0", "op2": "X1"}),
            Instr("PSHUFD_X_X", {"op1": "X1", "op2": "X0"})]
    p = predict(model, TEST_ISA, code)
    assert p.latency_bound <= 2.5
    leg = LegacyAnalyzer(model, TEST_ISA)
    pl = leg.predict(code)
    assert pl.latency_bound >= 8.0  # scalar-latency overestimate
    # the machine agrees with the per-pair model
    c = measure(snb_machine, code)
    assert c.cycles == pytest.approx(p.cycles, abs=0.6)


def test_legacy_ignores_flags_cmc(skl_model):
    """§7.2: IACA reports CMC throughput 0.25; reality (and our predictor) 1."""
    code = [Instr("CMC", {})]
    ours = predict(skl_model, TEST_ISA, code)
    legacy = LegacyAnalyzer(skl_model, TEST_ISA).predict(code)
    assert ours.cycles == pytest.approx(1.0, abs=0.05)
    assert legacy.cycles == pytest.approx(0.25, abs=0.05)


def test_legacy_ignores_memory_dependence(skl_model):
    """§7.2: store+load to the same address predicted at ~1 cycle by IACA."""
    code = [Instr("MOV_M64_R64", {"mem": "RB0", "op1": "R1"}),
            Instr("MOV_R64_M64", {"op1": "R1", "mem": "RB0"})]
    ours = predict(skl_model, TEST_ISA, code)
    legacy = LegacyAnalyzer(skl_model, TEST_ISA).predict(code)
    assert ours.latency_bound > legacy.latency_bound


def test_prediction_matches_machine_throughput(skl_machine, skl_model):
    """Predictor vs machine on independent sequences (port-bound regime)."""
    for name in ("ADD_R64_R64", "PADDD_X_X", "IMUL_R64_R64", "MULPS_X_X"):
        pool = RegPool()
        code = independent_seq(TEST_ISA[name], pool, 8)
        pred = predict(skl_model, TEST_ISA, code)
        meas = measure(skl_machine, code)
        assert meas.cycles == pytest.approx(pred.cycles, rel=0.25), name


def test_port_pressure_reported(skl_model):
    code = [Instr("MOVQ2DQ_X_X", {"op1": "X0", "op2": "X1"})]
    p = predict(skl_model, TEST_ISA, code)
    assert p.port_pressure["0"] > 1.0  # 1 pinned + share of p015


def test_unknown_instruction_raises_typed_error(skl_model):
    """An uncharacterized variant must surface as UnknownInstructionError
    (listing the missing specs), not a bare KeyError from PerfModel."""
    from repro.core.predictor import UnknownInstructionError

    code = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"}),
            Instr("LFENCE", {}),  # serializing: never characterized (§8)
            Instr("JMP_R64", {"op1": "R2"})]
    with pytest.raises(UnknownInstructionError) as ei:
        predict(skl_model, TEST_ISA, code)
    assert ei.value.missing == ["JMP_R64", "LFENCE"]
    assert "JMP_R64" in str(ei.value)
    with pytest.raises(UnknownInstructionError):
        LegacyAnalyzer(skl_model, TEST_ISA).predict(code)

"""Device-mesh substrate: resolution, placement, locks, graceful fallback.

These run under the suite's normal single-device jax, so they cover the
spec/placement machinery and the single-device degradation of every knob
(the ``devices=4``-on-a-1-device-host case must silently stay on the PR-5
path).  True multi-device behavior — mesh sharding, bit-identity at 2 and
4 forced host devices, disjoint campaign placement — lives in
``test_multidevice.py`` (subprocesses, XLA_FLAGS must precede jax import).
"""
import pytest

from repro.core.batch_sim import BatchSimMachine
from repro.core.device_mesh import (ENV_DEVICES, dispatch_lock, jax_devices,
                                    lane_mesh, partition, resolve_devices)
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_SKL

jax = pytest.importorskip("jax")


def _wave(n=12, seed=0):
    import random
    rng = random.Random(seed)
    specs = ["ADD_R64_R64", "IMUL_R64_R64", "MULPS_X_X", "DIV_R64"]
    out = []
    for _ in range(n):
        body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                               rng.randint(3, 8))
        out.append(body * rng.randint(2, 5))
    return out


# ---------------------------------------------------------------------------
# resolve_devices: every accepted spelling, clamped to the host
# ---------------------------------------------------------------------------


def test_resolve_devices_spellings(monkeypatch):
    devs = jax_devices()
    assert devs == tuple(jax.devices())
    monkeypatch.delenv(ENV_DEVICES, raising=False)
    assert resolve_devices(None) == devs          # default: all
    assert resolve_devices("all") == devs
    assert resolve_devices(len(devs)) == devs
    assert resolve_devices(str(len(devs))) == devs
    assert resolve_devices(1) == devs[:1]
    # over-ask degrades gracefully to everything the host has
    assert resolve_devices(64) == devs
    assert resolve_devices(0) == devs[:1]         # clamped up to 1
    # explicit sequences pass through untouched
    assert resolve_devices(devs[:1]) == devs[:1]


def test_resolve_devices_env(monkeypatch):
    devs = jax_devices()
    monkeypatch.setenv(ENV_DEVICES, "1")
    assert resolve_devices(None) == devs[:1]
    monkeypatch.setenv(ENV_DEVICES, "all")
    assert resolve_devices(None) == devs
    # the env knob only fills in for spec=None
    assert resolve_devices(len(devs)) == devs


# ---------------------------------------------------------------------------
# partition / locks / meshes
# ---------------------------------------------------------------------------


def test_partition_shapes():
    devs = list(range(4))   # ids suffice: partition never touches jax
    assert partition(devs, 2) == [(0, 1), (2, 3)]
    assert partition(devs, 3) == [(0,), (1,), (2, 3)]
    assert partition(devs, 4) == [(0,), (1,), (2,), (3,)]
    # fewer devices than machines: round-robin shared singletons
    assert partition(devs[:2], 5) == [(0,), (1,), (0,), (1,), (0,)]
    # no devices (no jax): empty groups, machines keep default placement
    assert partition((), 3) == [(), (), ()]
    assert partition(devs, 0) == []
    # disjointness whenever there are enough devices
    groups = partition(devs, 2)
    assert not (set(groups[0]) & set(groups[1]))


def test_dispatch_lock_identity():
    devs = jax_devices()
    a = dispatch_lock(devs[:1])
    assert dispatch_lock(devs[:1]) is a           # same subset, same lock
    assert dispatch_lock(()) is dispatch_lock(())   # host fallback lock
    assert dispatch_lock(()) is not a


def test_lane_mesh_memoized():
    devs = jax_devices()
    m = lane_mesh(devs[:1])
    assert lane_mesh(devs[:1]) is m
    assert m.n == 1 and m.key == (devs[0].id,)
    assert m.mesh.axis_names == ("lanes",)


# ---------------------------------------------------------------------------
# graceful single-device fallback + knob threading
# ---------------------------------------------------------------------------


def test_devices_overask_falls_back_single_device():
    """devices=4 on a 1-device host must stay on the single-device path
    and produce numpy-identical results (CPU CI without forced devices)."""
    codes = _wave()
    base = BatchSimMachine(SIM_SKL, TEST_ISA, backend="numpy")
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", devices=4)
    a = base.run_batch(codes)
    b = m.run_batch(codes)
    assert all(x.cycles == y.cycles and x.port_uops == y.port_uops
               for x, y in zip(a, b))
    st = m.device_stats()
    if len(jax_devices()) == 1:
        assert st["mesh"] is False
    assert st["devices"] == [d.id for d in resolve_devices(4)]
    # per-device counters attribute every real lane
    assert sum(c["lanes"] for c in st["per_device"].values()) >= len(codes)
    assert all(c["compiles"] <= len(c["buckets"])
               for c in st["per_device"].values())


def test_set_devices_rebuilds_executor():
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", devices=1)
    codes = _wave(8)
    first = m.run_batch(codes)
    assert m.device_stats() != {}
    m.set_devices("all")
    assert m.device_stats() == {}      # executor dropped, rebuilt lazily
    assert [c.cycles for c in m.run_batch(codes)] == \
        [c.cycles for c in first]


def test_sim_machine_forwards_devices():
    sm = SimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1,
                    devices=1)
    codes = _wave(8)
    got = sm.run_batch(codes)
    assert sm._batch.devices == 1
    sm.set_devices("all")
    assert sm._batch.devices == "all"
    ref = SimMachine(SIM_SKL, TEST_ISA).run_batch(codes)
    assert [c.cycles for c in got] == [c.cycles for c in ref]


def test_batch_predictor_devices_knob(skl_model):
    from repro.service.batch_predictor import BatchPredictor
    sm = SimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    bp = BatchPredictor(skl_model, TEST_ISA, machine=sm)
    blocks = _wave(6)
    a = bp.simulate_batch(blocks)
    b = bp.simulate_batch(blocks, devices=1)
    assert sm.devices == 1
    assert a == b


# ---------------------------------------------------------------------------
# telemetry surfacing: EngineStats.as_dict / characterize numeric guard
# ---------------------------------------------------------------------------


def test_engine_stats_surfaces_device_telemetry():
    from repro.core.engine import Experiment, MeasurementEngine
    sm = SimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    eng = MeasurementEngine(sm)
    eng.submit([Experiment.of(c) for c in _wave(6)])
    d = eng.stats.as_dict()["device"]
    assert d["backend"] == "jax" and d["kernel_calls"] >= 1
    assert set(d["per_device"]) == {dev.id for dev in resolve_devices()}


def test_characterize_engine_stats_with_device_snapshot():
    """The engine-stats delta in characterize must skip the non-numeric
    device snapshot instead of crashing on dict arithmetic."""
    from repro.core.characterize import characterize
    from repro.core.engine import MeasurementEngine
    sm = SimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1)
    model = characterize(MeasurementEngine(sm), TEST_ISA,
                         ["ADD_R64_R64", "MUL_R64"])
    es = model.engine_stats
    assert es["requests"] > 0
    assert isinstance(es["device"], dict)
    assert es["device"].get("backend") == "jax"

import pytest

# NOTE: do NOT set XLA_FLAGS here — tests must see the real single-device
# CPU platform; only launch/dryrun.py overrides the device count.


@pytest.fixture(scope="session")
def skl_machine():
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SKL

    return SimMachine(SIM_SKL, TEST_ISA)


@pytest.fixture(scope="session")
def hsw_machine():
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_HSW

    return SimMachine(SIM_HSW, TEST_ISA)


@pytest.fixture(scope="session")
def snb_machine():
    from repro.core.isa import TEST_ISA
    from repro.core.simulator import SimMachine
    from repro.core.uarch import SIM_SNB

    return SimMachine(SIM_SNB, TEST_ISA)


@pytest.fixture(scope="session")
def skl_blocking(skl_machine):
    from repro.core.blocking import find_blocking_instructions
    from repro.core.isa import TEST_ISA

    return find_blocking_instructions(skl_machine, TEST_ISA)


CHAR_SUBSET = [
    "ADD_R64_R64", "XOR_R64_R64", "ADC_R64_R64", "IMUL_R64_R64", "MUL_R64",
    "DIV_R64", "SHLD_R64_R64_I8", "CMC", "TEST_R64_R64", "SETC_R8",
    "CMOVBE_R64_R64", "MOV_R64_M64", "MOV_M64_R64", "ADD_R64_M64",
    "PADDD_X_X", "MULPS_X_X", "MOVQ2DQ_X_X", "AESDEC_X_X", "PSHUFD_X_X",
    "MOV_R64_R64", "MOVSX_R64_R32", "BSWAP_R32", "BSWAP_R64", "POPCNT_R64_R64",
]


@pytest.fixture(scope="session")
def skl_model(skl_machine, skl_blocking):
    from repro.core.characterize import characterize
    from repro.core.isa import TEST_ISA

    return characterize(skl_machine, TEST_ISA, CHAR_SUBSET,
                        blocking=skl_blocking)

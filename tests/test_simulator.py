"""Simulated-machine behaviors the paper's algorithms must contend with."""
import pytest

from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq, measure
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_SKL


@pytest.fixture(scope="module")
def m():
    return SimMachine(SIM_SKL, TEST_ISA)


def test_overhead_cancellation(m):
    """Raw runs include harness overhead; Algorithm-2 differencing removes
    it exactly (deterministic machine)."""
    seq = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"})]
    raw = m.run(seq * 10)
    assert raw.cycles > SIM_SKL.overhead_cycles
    c = measure(m, seq)
    assert c.cycles == pytest.approx(1.0, abs=0.05)  # dependent chain: lat 1


def test_zero_idiom_breaks_dependency(m):
    """XOR R,R is dependency-breaking AND executes zero μops on SKL-like."""
    slow = [Instr("IMUL_R64_R64", {"op1": "R0", "op2": "R1"})]
    mixed = [Instr("IMUL_R64_R64", {"op1": "R0", "op2": "R1"}),
             Instr("XOR_R64_R64", {"op1": "R0", "op2": "R0"})]
    c_slow = measure(m, slow)
    c_mixed = measure(m, mixed)
    assert c_slow.cycles == pytest.approx(3.0, abs=0.05)
    assert c_mixed.cycles < c_slow.cycles  # chain broken
    assert c_mixed.total_uops == pytest.approx(1.0, abs=0.05)  # XOR: 0 μops


def test_move_elimination_partial(m):
    """In a chained MOV sequence about 1/3 execute (the paper's observation
    motivating MOVSX for chains)."""
    seq = [Instr("MOV_R64_R64", {"op1": f"R{(i + 1) % 8}", "op2": f"R{i % 8}"})
           for i in range(8)]
    c = measure(m, seq)
    frac_executed = c.total_uops / len(seq)
    assert 0.25 < frac_executed < 0.45


def test_movsx_never_eliminated(m):
    seq = [Instr("MOVSX_R64_R32", {"op1": f"R{(i + 1) % 8}", "op2": f"R{i % 8}"})
           for i in range(8)]
    c = measure(m, seq)
    assert c.total_uops / len(seq) == pytest.approx(1.0, abs=0.02)
    assert c.cycles / len(seq) == pytest.approx(1.0, abs=0.02)


def test_divider_not_pipelined(m):
    """Independent DIVs are limited by divider occupancy, not port count."""
    pool = RegPool()
    # give each DIV a distinct implicit-free setup: op2 distinct, but the
    # implicit RDX dependency still serializes -> measured >> occupancy
    seq = independent_seq(TEST_ISA["DIV_R64"], pool, 4)
    c = measure(m, seq)
    assert c.cycles / 4 >= 6  # occupancy floor


def test_divider_value_dependence(m):
    lo = [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "low")]
    hi = [Instr("DIV_R64", {"op1": "R0", "op2": "R1"}, "high")]
    assert measure(m, hi).cycles > measure(m, lo).cycles


def test_store_to_load_forwarding(m):
    """Store->load round trip is faster than store + full load latency."""
    rt = measure(m, [
        Instr("MOV_M64_R64", {"mem": "RB0", "op1": "R1"}),
        Instr("MOV_R64_M64", {"op1": "R1", "mem": "RB0"}),
    ])
    assert rt.cycles < 1 + SIM_SKL.load_latency + 2
    assert rt.cycles >= SIM_SKL.store_forward_latency


def test_port_counters_sum(m):
    """Counters attribute each μop to exactly one port."""
    seq = independent_seq(TEST_ISA["PADDD_X_X"], RegPool(), 6)
    c = measure(m, seq)
    assert c.total_uops == pytest.approx(6.0, abs=0.05)
    used = {p for p, v in c.port_uops.items() if v > 0.05}
    assert used == {"0", "1", "5"}


def test_frontend_issue_width_limits(m):
    """More μops than width*cycles cannot retire: NOP-free ALU flood."""
    pool = RegPool()
    seq = independent_seq(TEST_ISA["ADD_R64_R64"], pool, 16)
    c = measure(m, seq)
    # 4 ALU ports but issue width 4 -> 4/cycle
    assert c.cycles / 16 >= 0.24


def test_partial_register_stall(m):
    """§5.2.1: reading a 64-bit register after an 8-bit write stalls; a
    width-matched MOVSX read does not — the reason the paper's chains use
    MOVSX variants."""
    from repro.core.uarch import SIM_SKL as UA

    # SETC writes 8 bits of R1; ADD reads 64 bits of R1 -> stall
    stalled = measure(m, [
        Instr("SETC_R8", {"op1": "R1"}),
        Instr("ADD_R64_R64", {"op1": "R2", "op2": "R1"}),
        Instr("TEST_R64_R64", {"op1": "R2", "op2": "R2"}),  # close flags loop
    ])
    # width-matched: MOVSX reads only the written byte
    clean = measure(m, [
        Instr("SETC_R8", {"op1": "R1"}),
        Instr("MOVSX_R64_R8", {"op1": "R2", "op2": "R1"}),
        Instr("TEST_R64_R64", {"op1": "R2", "op2": "R2"}),
    ])
    assert stalled.cycles == pytest.approx(
        clean.cycles + UA.partial_stall_penalty, abs=0.1)

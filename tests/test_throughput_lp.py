"""Throughput (§4.2 / §5.3): measured vs LP-computed, and the LP itself."""
import random

import pytest

from repro.core.isa import TEST_ISA
from repro.core.lp import (_bisect_flow, cut_bound, port_bound_from_usage,
                           throughput_lp, union_closure)
from repro.core.throughput import computed_throughput, measure_throughput


def test_lp_single_uop():
    assert throughput_lp({frozenset("0156"): 1}) == pytest.approx(0.25)
    assert throughput_lp({frozenset("0"): 1}) == pytest.approx(1.0)


def test_lp_overlapping_combos():
    # 2 uops on p01 + 2 uops on p0 -> load: p0 gets 2, p1 gets 2 -> z=2
    u = {frozenset("01"): 2, frozenset("0"): 2}
    assert throughput_lp(u) == pytest.approx(2.0)
    # 1*p0+1*p015: p0:1, split the other over p1/p5 -> z=1
    u2 = {frozenset("0"): 1, frozenset("015"): 1}
    assert throughput_lp(u2) == pytest.approx(1.0)


def test_lp_matches_maxflow_fallback():
    cases = [
        {frozenset("01"): 3, frozenset("12"): 2, frozenset("2"): 1},
        {frozenset("0156"): 4, frozenset("06"): 2},
        {frozenset("0"): 5},
    ]
    for u in cases:
        ports = sorted(set().union(*u))
        assert throughput_lp(u) == pytest.approx(
            _bisect_flow(u, ports), abs=1e-4)


def test_cut_bound_equals_lp_on_random_usages():
    """The min-cut closed form (service fast path) is the LP optimum."""
    rng = random.Random(0)
    ports = "01234567"
    for _ in range(150):
        usage = {frozenset(rng.sample(ports, rng.randint(1, 4))):
                 rng.randint(1, 6)
                 for _ in range(rng.randint(1, 5))}
        assert cut_bound(usage) == pytest.approx(throughput_lp(usage),
                                                 abs=1e-6)
        assert port_bound_from_usage(usage) == pytest.approx(
            throughput_lp(usage), abs=1e-6)


def test_union_closure():
    combos = [frozenset("01"), frozenset("2"), frozenset("01")]
    closed = union_closure(combos)
    assert set(closed) == {frozenset("01"), frozenset("2"),
                           frozenset("012")}
    assert union_closure([frozenset(str(i)) for i in range(20)],
                         cap=100) is None
    assert union_closure([]) == []


def test_measured_throughput_alu(skl_machine):
    r = measure_throughput(skl_machine, TEST_ISA, "ADD_R64_R64")
    assert r.measured == pytest.approx(0.25, abs=0.02)
    assert set(r.by_seq_len) == {1, 2, 4, 8}
    # a single instance chains with itself through op1 (rw): slower
    assert r.by_seq_len[1] >= r.by_seq_len[8]


def test_implicit_flags_limit_fog_throughput(skl_machine):
    """Def. 2 throughput of CMC is 1 (flags RMW serializes); Intel-definition
    (from ports) is 0.25 — the two definitions genuinely differ (§4.2)."""
    r = measure_throughput(skl_machine, TEST_ISA, "CMC")
    assert r.measured == pytest.approx(1.0, abs=0.05)


def test_breaker_variant_helps_adc(skl_machine):
    r = measure_throughput(skl_machine, TEST_ISA, "ADC_R64_R64")
    assert r.with_breakers is not None
    # without breakers the flags chain forces ~1 cycle/instr; the breaker
    # variant beats it despite consuming execution resources itself
    assert r.measured == pytest.approx(1.0, abs=0.1)
    assert r.with_breakers < r.measured


def test_divider_high_low(skl_machine):
    r = measure_throughput(skl_machine, TEST_ISA, "DIV_R64")
    assert r.high_value is not None
    assert r.high_value > r.measured


def test_computed_throughput_from_ports(skl_model):
    im = skl_model["ADD_R64_R64"]
    assert im.throughput.computed_from_ports == pytest.approx(0.25, abs=0.01)
    # dividers are excluded from LP computation (not fully pipelined)
    assert skl_model["DIV_R64"].throughput.computed_from_ports is None


def test_intel_vs_fog_definitions_diverge(skl_model):
    """CMC: computed-from-ports 0.25 vs measured 1.0."""
    im = skl_model["CMC"]
    assert im.throughput.computed_from_ports == pytest.approx(0.25, abs=0.01)
    assert im.throughput.measured == pytest.approx(1.0, abs=0.05)

"""Property-based tests (hypothesis): the paper's central correctness claims
made mechanically checkable against randomly drawn hidden ground truths."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.blocking import find_blocking_instructions
from repro.core.isa import GPR, ISA, InstrSpec, op
from repro.core.latency import LatencyAnalyzer
from repro.core.lp import _bisect_flow, throughput_lp
from repro.core.port_usage import infer_port_usage
from repro.core.simulator import SimMachine
from repro.core.throughput import measure_throughput
from repro.core.uarch import InstrBehavior, UArch, random_uarch_and_isa, uop

SET = settings(max_examples=20, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


@given(seed=st.integers(0, 10_000))
@SET
def test_algorithm1_recovers_random_port_usage(seed):
    """For ANY hidden ground truth (with blocking instructions available),
    Algorithm 1 recovers the exact port-usage multiset."""
    ua, isa, truth = random_uarch_and_isa(seed)
    m = SimMachine(ua, isa)
    blocking = find_blocking_instructions(m, isa, extensions=("BASE",))
    for name, expect in truth.items():
        got = infer_port_usage(m, isa, name, blocking, max_latency=4).usage
        assert got == expect, (name, got, expect)


def _chain_isa(seed: int, lats):
    """ISA with a MOVSX-like chain instr + one multi-uop instr whose
    per-pair latencies are the hidden parameters."""
    isa = ISA()
    isa.add(InstrSpec("MOVSX_R64_R32", "MOVSX",
                      (op("op1", GPR, "w"), op("op2", GPR, "r", width=32))))
    isa.add(InstrSpec("TGT", "TGT",
                      (op("op1", GPR, "w"), op("op2", GPR, "r"))))
    l1, l2 = lats
    behaviors = {
        "MOVSX_R64_R32": InstrBehavior((uop(frozenset("01"), ("op2",),
                                            ("op1",)),)),
        "TGT": InstrBehavior((
            uop(frozenset("0"), ("op2",), ("%0",), l1),
            uop(frozenset("01"), ("%0",), ("op1",), l2),
        )),
    }
    return ISA([s for s in isa]), UArch(f"lat{seed}", tuple("012"), 4,
                                        behaviors, overhead_cycles=30)


@given(l1=st.integers(1, 9), l2=st.integers(1, 9))
@SET
def test_chain_latency_recovers_random_values(l1, l2):
    """Dependency-chain inference recovers lat(op2,op1) = l1+l2 exactly."""
    isa, ua = _chain_isa(0, (l1, l2))
    m = SimMachine(ua, isa)
    la = LatencyAnalyzer(m, isa)
    r = la.analyze("TGT")
    assert r.get("op2", "op1").value == pytest.approx(l1 + l2, abs=0.05)


@given(st.dictionaries(
    keys=st.frozensets(st.sampled_from("012345"), min_size=1, max_size=4),
    values=st.integers(1, 5), min_size=1, max_size=4))
@SET
def test_lp_equals_maxflow(usage):
    """The §5.3.2 LP agrees with the independent bisection+max-flow solver."""
    ports = sorted(set().union(*usage))
    assert throughput_lp(usage) == pytest.approx(
        _bisect_flow(usage, ports), abs=1e-4)


@given(st.dictionaries(
    keys=st.frozensets(st.sampled_from("0123"), min_size=1, max_size=3),
    values=st.integers(1, 4), min_size=1, max_size=3))
@SET
def test_lp_lower_bounds(usage):
    """z* >= total/|ports| and z* >= μ(pc)/|pc| for every combination, and
    z* <= total μops (trivial upper bound)."""
    z = throughput_lp(usage)
    total = sum(usage.values())
    ports = set().union(*usage)
    assert z >= total / len(ports) - 1e-6
    for pc, mu in usage.items():
        assert z >= mu / len(pc) - 1e-6
    assert z <= total + 1e-6


@given(seed=st.integers(0, 3000))
@SET
def test_measured_throughput_ge_lp(seed):
    """Fog-measured throughput can never beat the Intel/LP bound (§4.2:
    Def. 2 yields higher cycle counts than Def. 1)."""
    ua, isa, truth = random_uarch_and_isa(seed, n_instr=3)
    m = SimMachine(ua, isa)
    for name, usage in truth.items():
        meas = measure_throughput(m, isa, name).measured
        lp = throughput_lp(usage)
        assert meas >= lp - 0.05, (name, meas, lp)


@given(seed=st.integers(0, 3000))
@SET
def test_simulator_port_counts_conserve_uops(seed):
    """Per-port counters sum to the total μop count of the program."""
    ua, isa, truth = random_uarch_and_isa(seed, n_instr=4)
    m = SimMachine(ua, isa)
    from repro.core.machine import RegPool, independent_seq

    pool = RegPool()
    for name, usage in truth.items():
        seq = independent_seq(isa[name], pool, 5)
        c = m.run(seq)
        assert c.total_uops == 5 * sum(usage.values())


# ---------------------------------------------------------------------------
# service protocol: textual block format round-trips
# ---------------------------------------------------------------------------

_IDENT = st.text(alphabet=st.sampled_from(
    "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_"), min_size=1, max_size=12)


@st.composite
def _blocks(draw):
    from repro.core.simulator import Instr
    n = draw(st.integers(0, 6))
    code = []
    for _ in range(n):
        spec = draw(_IDENT)
        regs = draw(st.dictionaries(_IDENT, _IDENT, max_size=4))
        hint = draw(st.sampled_from(["low", "high"]))
        code.append(Instr(spec, regs, hint))
    return code


@given(code=_blocks())
@SET
def test_format_block_is_exact_inverse_of_parse_block(code):
    """format_block ∘ parse_block == id on the block domain: every
    formattable block (any spec/operand identifiers, any value hint)
    survives a serialize→parse round trip exactly."""
    from repro.service.protocol import format_block, parse_block

    text = format_block(code)
    assert parse_block(text) == code
    # and the canonical text form is a fixed point of the round trip
    assert format_block(parse_block(text)) == text

"""The TPU adaptation, end to end on the simulated TPU-unit core:
ports = {MXU, VPU, XLU, LSU, SFU}, instructions = kernel-level tile ops.
Algorithm 1 + the latency chains recover the hidden unit occupancy of fused
kernels (flash-attention tile, SSD chunk tile, ...) exactly — the claim
DESIGN.md §2 makes about transferring the paper's method to TPUs."""
import pytest

from repro.core.blocking import find_blocking_instructions
from repro.core.latency import LatencyAnalyzer
from repro.core.machine import isolation_ports
from repro.core.port_usage import infer_port_usage
from repro.core.simulator import SimMachine
from repro.core.uarch import make_tpu_sim


@pytest.fixture(scope="module")
def tpu():
    ua, isa, truth = make_tpu_sim()
    return SimMachine(ua, isa), isa, truth


def test_blocking_kernels_discovered(tpu):
    """Each unit's saturator is discovered as its blocking instruction —
    the simulated counterpart of kernels/microbench.py."""
    m, isa, _ = tpu
    blk = find_blocking_instructions(m, isa, extensions=("BASE",))
    got = {next(iter(pc)): name for pc, name in blk.instrs.items()
           if len(pc) == 1}
    assert got["MXU"] == "MATMUL_TILE"
    assert got["VPU"] == "FMA_TILE"
    assert got["LSU"] == "COPY_TILE"
    assert got["SFU"] == "EXP_TILE"
    assert got["XLU"] == "TRANSPOSE_TILE"


@pytest.mark.parametrize("kernel", ["FLASH_ATTN_TILE", "SSD_CHUNK_TILE",
                                    "SOFTMAX_TILE", "RMSNORM_TILE",
                                    "GATHER_TILE"])
def test_unit_occupancy_recovered(tpu, kernel):
    """Algorithm 1 recovers the exact unit-occupancy multiset of every
    fused kernel op (e.g. flash-attn tile = 2*MXU + 1*VPU + 1*SFU + 1*LSU)."""
    m, isa, truth = tpu
    blk = find_blocking_instructions(m, isa, extensions=("BASE",))
    pu = infer_port_usage(m, isa, kernel, blk, max_latency=12)
    assert pu.usage == truth[kernel], (pu.usage, truth[kernel])


def test_flash_attn_tile_composition(tpu):
    m, isa, truth = tpu
    blk = find_blocking_instructions(m, isa, extensions=("BASE",))
    pu = infer_port_usage(m, isa, "FLASH_ATTN_TILE", blk, max_latency=12)
    assert pu.usage == {frozenset(["MXU"]): 2, frozenset(["VPU"]): 1,
                        frozenset(["SFU"]): 1, frozenset(["LSU"]): 1}


def test_isolation_is_unambiguous_here_but_method_matches(tpu):
    """On single-port units isolation already identifies the ports; the
    point is the *count* attribution for multi-μop fused kernels."""
    m, isa, _ = tpu
    iso = isolation_ports(m, isa["SSD_CHUNK_TILE"])
    assert iso["MXU"] == pytest.approx(2.0, abs=0.1)
    assert iso["LSU"] == pytest.approx(1.0, abs=0.1)


def test_kernel_latency_chain(tpu):
    """Pipeline latency through a fused kernel: flash tile = 4+2+3+2+1."""
    m, isa, _ = tpu
    from repro.core.machine import measure
    from repro.core.simulator import Instr

    # self-chain: op1 -> op2 of the next instance
    seq = [Instr("FLASH_ATTN_TILE", {"op1": "R0", "op2": "R1"}),
           Instr("FLASH_ATTN_TILE", {"op1": "R1", "op2": "R0"})]
    c = measure(m, seq)
    assert c.cycles / 2 == pytest.approx(12.0, abs=0.1)

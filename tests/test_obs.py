"""Observability layer: tracer semantics, exporters, metrics registry,
legacy stats-shape pinning, service trace ids / access log, and the
"tracing must not perturb results" bit-identity guarantee.

The load-bearing guarantees:
  * disabled tracing is a shared stateless no-op (same singleton back from
    every call site — no allocation on the off path);
  * spans nest per thread and are reentrant across the Campaign pool;
  * the Chrome trace-event export is schema-valid (ph/ts/dur/pid/tid/args,
    thread-name metadata, counter and device tracks);
  * characterize with tracing ON produces byte-identical XML to the
    committed model artifact;
  * the legacy stats dict shapes (EngineStats.as_dict, server stats) are
    pinned while the metrics registry is the source of truth.
"""
import json
import logging
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import pytest

from repro.core import model_io
from repro.core.characterize import characterize
from repro.core.engine import Campaign, MeasurementEngine
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES
from repro.obs import export, metrics, tracer
from repro.obs.tracer import NULL_SPAN, Tracer, set_tracer

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture
def traced():
    """Install a fresh enabled tracer; restore the previous one after."""
    tr = Tracer(enabled=True)
    prev = set_tracer(tr)
    yield tr
    set_tracer(prev)


@pytest.fixture
def disabled():
    tr = Tracer(enabled=False)
    prev = set_tracer(tr)
    yield tr
    set_tracer(prev)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_disabled_is_shared_noop(disabled):
    # every call site gets the same stateless singleton: no allocation
    sp = tracer.span("x", wave=3)
    assert sp is NULL_SPAN
    assert tracer.span("y") is sp
    with sp as inner:
        assert inner is sp
        inner.set(k=1)  # no-op, chainable
    tracer.instant("i", a=1)
    tracer.counter("c", 42)
    tracer.emit_span("e", 0, 10)
    assert disabled.events() == []
    assert not tracer.enabled()


def test_disabled_wait_lock_still_locks(disabled):
    lock = threading.Lock()
    with tracer.wait_lock(lock, "w"):
        assert lock.locked()
    assert not lock.locked()
    with tracer.wait_lock(None, "w"):  # no lock configured: pure no-op
        pass
    assert disabled.events() == []


def test_span_nesting_and_attrs(traced):
    with tracer.span("outer", a=1) as out_sp:
        with tracer.span("inner") as in_sp:
            in_sp.set(b=2)
        out_sp.set(c=3)
    evs = traced.events()
    names = [e["name"] for e in evs]
    assert names == ["inner", "outer"]  # children close first
    inner, outer = evs
    assert inner["ph"] == outer["ph"] == "X"
    assert inner["args"] == {"b": 2}
    assert outer["args"] == {"a": 1, "c": 3}
    assert inner["t0"] >= outer["t0"]
    assert inner["dur"] <= outer["dur"]


def test_trace_id_inheritance(traced):
    with tracer.span("request", trace_id="abc123"):
        with tracer.span("child"):
            with tracer.span("grandchild", own=1):
                pass
    by_name = {e["name"]: e for e in traced.events()}
    assert by_name["child"]["args"] == {"trace_id": "abc123"}
    assert by_name["grandchild"]["args"] == {"own": 1, "trace_id": "abc123"}


def test_reentrant_across_threads(traced):
    # Campaign-style pool: per-thread stacks must not interleave
    def work(i):
        with tracer.span("outer", worker=i):
            with tracer.span("inner", worker=i):
                pass

    with ThreadPoolExecutor(max_workers=4) as pool:
        list(pool.map(work, range(16)))
    evs = traced.events()
    assert len(evs) == 32
    for ev in evs:
        assert ev["ph"] == "X" and ev["dur"] >= 0
    # every worker's inner span is attributed to the same thread as its
    # outer span
    pairs: dict = {}
    for ev in evs:
        pairs.setdefault(ev["args"]["worker"], set()).add(ev["tid"])
    assert all(len(tids) == 1 for tids in pairs.values())
    assert set(traced.thread_names()) == {e["tid"] for e in evs}


def test_wait_lock_measures_contention(traced):
    lock = threading.Lock()
    lock.acquire()
    t = threading.Timer(0.03, lock.release)
    t.start()
    with tracer.wait_lock(lock, "wave.lock_wait"):
        pass
    t.join()
    (ev,) = traced.events()
    assert ev["name"] == "wave.lock_wait"
    assert ev["dur"] >= 20e6  # waited >= 20ms, in ns


def test_emit_span_on_device_track(traced):
    tracer.emit_span("wave.kernel", traced.t0_ns, 5000,
                     track="device:0", lanes=8)
    (ev,) = traced.events()
    assert ev["tid"] == "device:0"
    assert traced.tracks() == ["device:0"]
    assert ev["args"] == {"lanes": 8}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _populate(tr):
    with tracer.span("scheduler.run", plans=2):
        with tracer.span("wave.run_batch", lanes=4):
            pass
    tracer.counter("scheduler.wave_width", 4)
    tracer.instant("mesh.partition", devices=0)
    tracer.emit_span("wave.kernel", tr.t0_ns + 100, 2000,
                     track="device:1", lanes=4)


def test_chrome_trace_schema(traced):
    _populate(traced)
    doc = export.chrome_trace(traced, process_name="repro-test")
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"ph": "M", "name": "process_name", "pid": traced.pid, "tid": 0,
            "args": {"name": "repro-test"}} in meta
    tnames = {e["tid"]: e["args"]["name"] for e in meta
              if e["name"] == "thread_name"}
    assert "device:1" in tnames.values()
    for ev in evs:
        assert set(ev) >= {"ph", "name", "pid", "tid", "args"}
        assert ev["pid"] == traced.pid
        if ev["ph"] != "M":
            assert ev["ts"] >= 0
    complete = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert "dur" in complete["scheduler.run"]
    assert complete["scheduler.run"]["args"] == {"plans": 2}
    # the device-track event landed on the synthetic track tid
    dev_tid = next(t for t, n in tnames.items() if n == "device:1")
    assert complete["wave.kernel"]["tid"] == dev_tid
    (cnt,) = [e for e in evs if e["ph"] == "C"]
    assert cnt["args"] == {"value": 4}
    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["s"] == "t"


def test_exporters_roundtrip(traced, tmp_path):
    _populate(traced)
    cpath = export.write_chrome_trace(tmp_path / "t.trace.json", traced)
    jpath = export.write_jsonl(tmp_path / "t.trace.jsonl", traced)
    json.loads(Path(cpath).read_text())  # valid single-document JSON
    a = export.load_events(cpath)
    b = export.load_events(jpath)
    key = lambda e: (e["name"], e["ph"], round(e["ts_us"], 3))  # noqa: E731
    assert sorted(map(key, a)) == sorted(map(key, b))
    by_name = {e["name"]: e for e in a}
    assert by_name["wave.kernel"]["tid_name"] == "device:1"
    assert by_name["wave.run_batch"]["dur_us"] >= 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_instruments():
    reg = metrics.MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(1.5)
    h = reg.histogram("h", keep=8)
    for v in range(16):
        h.observe(float(v))
    assert reg.counter("c") is reg.counter("c")  # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("c")
    snap = reg.snapshot()
    assert snap["c"] == {"type": "counter", "value": 3}
    assert snap["g"] == {"type": "gauge", "value": 1.5}
    hs = snap["h"]
    assert hs["type"] == "histogram"
    assert hs["count"] == 16 and hs["min"] == 0.0 and hs["max"] == 15.0
    # reservoir keeps the newest 8, but count/sum/min/max stay exact
    assert 8.0 <= hs["p50"] <= 15.0
    assert reg.value("c") == 3


def test_engine_stats_legacy_shape():
    m = SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)
    engine = MeasurementEngine(m)
    model = characterize(engine, TEST_ISA, ["ADD_R64_R64"])
    assert model.instructions
    stats = engine.stats.as_dict()
    assert list(stats) == ["requests", "cache_hits", "dedup_hits",
                           "executions", "machine_runs", "batches",
                           "evictions", "lowering_hits", "lowering_misses",
                           "lowering_evictions", "quarantined",
                           "bisect_retries", "degraded_chunks",
                           "hit_rate", "device"]
    assert stats["requests"] > 0
    # resilience counters are zero on a clean run (and as_dict drops the
    # quarantine/degraded detail maps entirely when empty)
    assert stats["quarantined"] == 0 and stats["degraded_chunks"] == 0
    assert "quarantine" not in stats and "degraded" not in stats
    # and the canonical registry carries the same numbers
    reg = metrics.MetricsRegistry()
    metrics.absorb_engine_stats(reg, stats)
    assert reg.value("engine.requests") == stats["requests"]
    assert metrics.legacy_engine_dict(reg) == {
        k: v for k, v in stats.items() if k != "device"}


# ---------------------------------------------------------------------------
# tracing must not perturb results
# ---------------------------------------------------------------------------


def test_characterize_traced_xml_bit_identical(traced):
    """Full-ISA characterize with tracing ON is byte-identical to an
    untraced run (and to the exported model artifact when one exists),
    and the trace contains the expected spans."""
    prev = set_tracer(Tracer(enabled=False))
    try:
        m0 = SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)
        want = model_io.to_xml(
            characterize(MeasurementEngine(m0), TEST_ISA), TEST_ISA)
    finally:
        set_tracer(traced)
    m = SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)
    got = model_io.to_xml(
        characterize(MeasurementEngine(m), TEST_ISA), TEST_ISA)
    assert got == want
    artifact = REPO / "experiments" / "models" / "sim_skl.xml"
    if artifact.exists():  # export_models.py output is local, not tracked
        assert got == artifact.read_text()
    names = {e["name"] for e in traced.events()}
    assert names >= {"characterize", "scheduler.run", "scheduler.drain",
                     "scheduler.execute", "engine.submit",
                     "engine.cache_probe", "engine.miss_wave",
                     "wave.run_batch", "wave.lower", "wave.pack",
                     "wave.kernel", "wave.extract"}


def test_campaign_worker_spans(traced):
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    Campaign(instr_names=["ADD_R64_R64", "MUL_R64"]).run(machines, TEST_ISA)
    evs = traced.events()
    workers = [e for e in evs if e["name"] == "campaign.worker"]
    assert len(workers) == len(machines)
    assert {w["args"]["uarch"] for w in workers} == set(SIM_UARCHES)
    (run,) = [e for e in evs if e["name"] == "campaign.run"]
    assert run["args"]["machines"] == len(machines)


# ---------------------------------------------------------------------------
# wave report
# ---------------------------------------------------------------------------


def test_wave_report_attribution(traced, tmp_path):
    from repro.analysis.wave_report import format_wave_report, wave_report

    m = SimMachine(SIM_UARCHES["sim_hsw"], TEST_ISA)
    characterize(MeasurementEngine(m), TEST_ISA,
                 ["ADD_R64_R64", "IMUL_R64_R64", "PADDD_X_X"])
    path = export.write_chrome_trace(tmp_path / "t.trace.json", traced)
    rep = wave_report(export.load_events(path))
    assert rep["waves"] > 0
    assert rep["stages"]["kernel"]["us"] > 0
    shares = [s["share"] for s in rep["stages"].values()]
    assert abs(sum(shares) + rep["lock_wait"]["share"] - 1.0) < 1e-9
    assert rep["bottleneck"].endswith(("-bound", "imbalanced", "idle"))
    assert rep["top_waves"]
    text = format_wave_report(rep)
    assert "bottleneck" in text and "lock_wait" in text


def test_wave_report_device_imbalance():
    from repro.analysis import wave_report as wr

    def dev(track, dur):
        return {"ph": "X", "name": "wave.kernel", "ts_us": 0.0,
                "dur_us": dur, "tid": track, "tid_name": track, "args": {}}

    rep = wr.wave_report([dev("device:0", 900.0), dev("device:1", 100.0)])
    assert rep["device_imbalance"] == pytest.approx(1.8)
    assert rep["bottleneck"] == "device-imbalanced"
    # lock-bound wins over stage attribution when wait dominates
    rep2 = wr.wave_report([
        {"ph": "X", "name": "wave.kernel", "ts_us": 0.0, "dur_us": 100.0,
         "tid": 1, "tid_name": "", "args": {}},
        {"ph": "X", "name": "wave.lock_wait", "ts_us": 0.0, "dur_us": 100.0,
         "tid": 1, "tid_name": "", "args": {}}])
    assert rep2["bottleneck"] == "lock-bound"


# ---------------------------------------------------------------------------
# service: trace ids, access log, stats shapes
# ---------------------------------------------------------------------------

SERVICE_NAMES = ["ADD_R64_R64", "IMUL_R64_R64", "CMC", "ADC_R64_R64"]


@pytest.fixture(scope="module")
def obs_model_dir(tmp_path_factory):
    machines = [SimMachine(SIM_UARCHES["sim_skl"], TEST_ISA)]
    models = Campaign(instr_names=SERVICE_NAMES).run(machines,
                                                     TEST_ISA).models
    out = tmp_path_factory.mktemp("obs_models")
    for name, model in models.items():
        (out / f"{name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    return out


def _service(obs_model_dir, **kw):
    from repro.service.registry import ModelRegistry
    from repro.service.server import PredictionService

    return PredictionService(ModelRegistry(obs_model_dir), **kw)


BLOCK = [("ADD_R64_R64", {"op1": "R0", "op2": "R1"})]


def _instrs(pairs):
    from repro.core.simulator import Instr

    return [Instr(n, ops) for n, ops in pairs]


def test_trace_ids_in_responses(obs_model_dir):
    with _service(obs_model_dir) as svc:
        code = _instrs(BLOCK)
        r1 = svc.predict("sim_skl", code)
        r2 = svc.predict("sim_skl", code)
        assert r1["ok"] and r2["ok"]
        assert r1["trace_id"] != r2["trace_id"]
        assert len(r1["trace_id"]) == 16
        batch = svc.predict_batch("sim_skl", [code, code])
        tids = {b["trace_id"] for b in batch}
        assert len(tids) == 1  # one explicit batch = one trace id
        assert tids.isdisjoint({r1["trace_id"], r2["trace_id"]})


def test_access_log_and_slow_request(obs_model_dir, tmp_path, caplog):
    log = tmp_path / "access.jsonl"
    with _service(obs_model_dir, access_log=str(log),
                  slow_request_us=0.0) as svc:
        code = _instrs(BLOCK)
        with caplog.at_level(logging.WARNING, logger="repro.service"):
            r1 = svc.predict("sim_skl", code)     # miss
            r2 = svc.predict("sim_skl", code)     # cache hit
            svc.predict_batch("sim_skl", [code])
    recs = [json.loads(line) for line in log.read_text().splitlines()]
    assert len(recs) == 3
    for rec in recs:
        assert set(rec) == {"ts", "trace_id", "endpoint", "batch",
                            "cache_hits", "wall_us", "ok"}
        assert rec["ok"] is True
    assert recs[0]["trace_id"] == r1["trace_id"]
    assert recs[0]["cache_hits"] == 0
    assert recs[1]["trace_id"] == r2["trace_id"]
    assert recs[1]["cache_hits"] == 1
    assert recs[2]["endpoint"] == "predict_batch"
    # budget 0 => every request is over budget
    slow = [r for r in caplog.records if "slow request" in r.message]
    assert len(slow) >= 3
    assert r1["trace_id"] in "".join(r.getMessage() for r in slow)


def test_server_stats_legacy_shape_and_metrics(obs_model_dir):
    with _service(obs_model_dir) as svc:
        code = _instrs(BLOCK)
        svc.predict("sim_skl", code)
        svc.predict("sim_skl", code)
        stats = svc.stats()
        # pinned legacy shape
        assert set(stats) == {"uptime_s", "endpoints", "cache", "coalescer",
                              "registry"}
        ep = stats["endpoints"]["predict"]
        assert ep["requests"] == 2 and ep["errors"] == 0
        assert ep["p50_us"] > 0 and ep["p99_us"] >= ep["p50_us"]
        assert stats["cache"]["hits"] == 1
        # canonical snapshot carries the same numbers
        snap = svc.metrics()
        assert snap["server.endpoint.predict.count"]["value"] == 2
        assert snap["server.cache.hits"]["value"] == 1
        hist = snap["server.endpoint.predict.latency_s"]
        assert hist["type"] == "histogram" and hist["count"] == 2


def test_serve_group_spans_carry_trace_ids(obs_model_dir, traced):
    with _service(obs_model_dir) as svc:
        code = _instrs(BLOCK)
        res = svc.predict("sim_skl", code)
    evs = traced.events()
    sg = [e for e in evs if e["name"] == "server.serve_group"]
    assert sg and sg[0]["args"]["trace_id"] == res["trace_id"]
    # nested predictor spans inherited the request's trace id
    pb = [e for e in evs if e["name"] == "predict.batch"]
    assert pb and pb[0]["args"]["trace_id"] == res["trace_id"]

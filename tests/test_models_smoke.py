"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED same-family config and run one forward/train step + one decode step
on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, load_config, runnable_cells
from repro.models import model as MF

B, S = 2, 32


def make_batch(cfg, with_labels=True):
    batch = {}
    if cfg.family == "vlm":
        pt = cfg.num_patch_tokens
        batch["patch_embeds"] = jnp.zeros((B, pt, cfg.d_model),
                                          cfg.compute_dtype)
        batch["tokens"] = jnp.ones((B, S - pt), jnp.int32)
        if with_labels:
            batch["labels"] = jnp.ones((B, S - pt), jnp.int32)
        return batch
    if cfg.family == "encdec":
        batch["audio_frames"] = jnp.zeros(
            (B, cfg.num_audio_frames, cfg.d_model), cfg.compute_dtype)
    batch["tokens"] = jnp.ones((B, S), jnp.int32)
    if with_labels:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = load_config(arch, smoke=True).replace(ssm_chunk=8)
    model = MF.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 20
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = load_config(arch, smoke=True).replace(ssm_chunk=8)
    model = MF.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, with_labels=False)
    logits, state = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state2 = jax.jit(model.decode_step)(params, state, tok)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(state2["pos"]) == int(state["pos"]) + 1


@pytest.mark.parametrize("arch", ["mamba2_2_7b", "zamba2_2_7b"])
def test_prefill_decode_consistency_ssm(arch):
    """Decode continuation must equal running the train path one token
    longer (state handoff correctness for the recurrent families)."""
    cfg = load_config(arch, smoke=True).replace(
        ssm_chunk=8, param_dtype=jnp.float32, compute_dtype=jnp.float32)
    model = MF.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, 100)
    logits_a, state = jax.jit(lambda p, b: model.prefill(p, b, pad_to=17))(
        params, {"tokens": toks[:, :-1]})
    logits_b, _ = model.decode_step(params, state, toks[:, -1])
    # reference: prefill over the full sequence
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["minitron_8b", "qwen3_8b", "phi3_mini_3_8b",
                                  "whisper_medium", "phi3_5_moe_42b"])
def test_decode_consistency_attention(arch):
    """prefill(S-1) + decode(1) logits == prefill(S) last logits.

    MoE uses a dropless capacity factor: with the production factor the
    *set of dropped tokens* legitimately differs between a 22-token prefill
    dispatch and a 2-token decode dispatch, so exact continuation only
    holds when no tokens overflow expert capacity."""
    cfg = load_config(arch, smoke=True).replace(
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        capacity_factor=8.0)
    model = MF.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 100)
    extra = ({"audio_frames": jnp.ones((2, cfg.num_audio_frames, cfg.d_model),
                                       jnp.float32) * 0.02}
             if cfg.family == "encdec" else {})
    _, state = jax.jit(lambda p, b: model.prefill(p, b, pad_to=12))(
        params, {"tokens": toks[:, :-1], **extra})
    logits_b, _ = model.decode_step(params, state, toks[:, -1])
    logits_full, _ = jax.jit(model.prefill)(params,
                                            {"tokens": toks, **extra})
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_analytics():
    """Analytic param_count (used for 6ND roofline math) must match the
    real initialized trees on smoke configs."""
    for arch in ARCH_IDS:
        cfg = load_config(arch, smoke=True)
        model = MF.build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        real = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = cfg.param_count()
        assert abs(real - analytic) / real < 0.12, (
            f"{arch}: real={real} analytic={analytic}")


def test_cell_skips_documented():
    cells = runnable_cells()
    for arch in ARCH_IDS:
        shapes = {s for a, s in cells if a == arch}
        if arch in ("mamba2_2_7b", "zamba2_2_7b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes


def test_input_specs_cover_all_cells():
    for arch, shape_name in runnable_cells():
        cfg = load_config(arch)
        specs = MF.input_specs(cfg, SHAPES[shape_name])
        assert "tokens" in specs
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_decode_fori_equals_scan():
    """The in-place (fori) decode loop is numerically identical to the
    scan-based baseline (the §Perf memory optimization must not change
    semantics)."""
    cfg = load_config("minitron_8b", smoke=True).replace(
        param_dtype=jnp.float32, compute_dtype=jnp.float32)
    m_scan = MF.build_model(cfg)
    m_fori = MF.build_model(cfg.replace(decode_loop="fori"))
    params = m_scan.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0, 100)
    _, state = jax.jit(lambda p, b: m_scan.prefill(p, b, pad_to=16))(
        params, {"tokens": toks})
    nxt = jnp.ones((2,), jnp.int32)
    la, sa = jax.jit(m_scan.decode_step)(params, state, nxt)
    lb, sb = jax.jit(m_fori.decode_step)(params, state, nxt)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5,
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5,
                                   rtol=1e-5)

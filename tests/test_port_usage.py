"""Algorithm 1 (§5.1.2) against planted ground truths."""
import pytest

from repro.core.blocking import find_blocking_instructions
from repro.core.isa import TEST_ISA
from repro.core.machine import isolation_ports
from repro.core.port_usage import infer_port_usage


def pu(machine, blocking, name, max_lat=8):
    return infer_port_usage(machine, TEST_ISA, name, blocking, max_lat)


def test_simple_alu(skl_machine, skl_blocking):
    r = pu(skl_machine, skl_blocking, "ADD_R64_R64")
    assert r.usage == {frozenset("0156"): 1}
    assert r.notation() == "1*p0156"


def test_movq2dq_isolation_fallacy(skl_machine, skl_blocking):
    """§7.3.3: isolation shows 1 μop on p0 + 0.5 on p1/p5 — the naive
    conclusion 1*p0+1*p15 is wrong; Algorithm 1 finds 1*p0+1*p015."""
    iso = isolation_ports(skl_machine, TEST_ISA["MOVQ2DQ_X_X"])
    assert iso["0"] == pytest.approx(1.0, abs=0.1)
    assert iso.get("1", 0) == pytest.approx(0.5, abs=0.15)
    assert iso.get("5", 0) == pytest.approx(0.5, abs=0.15)
    r = pu(skl_machine, skl_blocking, "MOVQ2DQ_X_X")
    assert r.usage == {frozenset("0"): 1, frozenset("015"): 1}


def test_adc_haswell(hsw_machine):
    """§5.1: isolation suggests 2*p0156; truth is 1*p0156+1*p06."""
    blocking = find_blocking_instructions(hsw_machine, TEST_ISA)
    r = pu(hsw_machine, blocking, "ADC_R64_R64")
    assert r.usage == {frozenset("0156"): 1, frozenset("06"): 1}


def test_multi_uop_with_memory(skl_machine, skl_blocking):
    r = pu(skl_machine, skl_blocking, "ADD_R64_M64")
    assert r.usage == {frozenset("23"): 1, frozenset("0156"): 1}


def test_store_instruction(skl_machine, skl_blocking):
    r = pu(skl_machine, skl_blocking, "MOV_M64_R64")
    assert r.usage == {frozenset("237"): 1, frozenset("4"): 1}


def test_total_uops_consistency(skl_machine, skl_blocking):
    for name in ("ADD_R64_R64", "MUL_R64", "MOVQ2DQ_X_X", "BSWAP_R64"):
        r = pu(skl_machine, skl_blocking, name)
        assert sum(r.usage.values()) == round(r.total_uops), name


def test_notation_sorted():
    from repro.core.port_usage import PortUsage

    p = PortUsage(usage={frozenset("23"): 1, frozenset("015"): 3})
    assert p.notation() == "3*p015+1*p23"

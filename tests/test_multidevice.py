"""Multi-device correctness tests.

These must run with >1 device while the rest of the suite sees exactly one,
so each test spawns a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=N and asserts on its output. Covered:

  * mesh-sharded wave execution bit-identical to the numpy backend at 1, 2
    and 4 devices (ragged/empty waves, jax and pallas backends), with the
    thin-chunk crossover clamping the mesh width per-device shard,
  * characterize-to-XML byte-identical across device counts and to the
    scalar oracle for every SIM_UARCH,
  * Campaign placing machines on disjoint device subsets with unchanged
    models,
  * MoE shard_map EP path == dense reference (loss parity),
  * GPipe pipeline over an axis == sequential layer stack,
  * int8-compressed psum ≈ exact psum (and exact for int values),
  * decode attention with a sequence-sharded KV cache == unsharded,
  * production mesh construction (both shapes).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _modern_jax() -> bool:
    """The sharded-training tests drive the modern mesh API
    (``jax.sharding.AxisType`` / ``jax.set_mesh`` / ``jax.shard_map``),
    which older jax releases (<= 0.4.x) don't ship."""
    try:
        import jax  # noqa: PLC0415
    except ImportError:
        return False
    return (hasattr(jax.sharding, "AxisType") and hasattr(jax, "set_mesh")
            and hasattr(jax, "shard_map"))


needs_modern_jax = pytest.mark.skipif(
    not _modern_jax(),
    reason="jax.sharding.AxisType / jax.set_mesh / jax.shard_map "
           "unavailable in this jax version")


def run_py(code: str, devices: int = 4, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_wave_bit_identity_across_device_counts():
    """Mesh-sharded wave execution (jax at 1/2/4 devices, pallas at 4)
    is bit-identical to the numpy backend on a ragged wave with empty
    sequences; the thin-chunk crossover routes on *per-device* shard
    width (mesh width clamps so no shard drops below min_lanes); warm
    waves never recompile."""
    out = run_py("""
import random
from repro.core.batch_sim import BatchSimMachine
from repro.core.isa import TEST_ISA
from repro.core.machine import RegPool, independent_seq
from repro.core.uarch import SIM_SKL

rng = random.Random(0)
specs = ["ADD_R64_R64", "IMUL_R64_R64", "MOV_R64_R64", "PADDD_X_X",
         "DIV_R64", "MULPS_X_X", "ADC_R64_R64"]
codes = []
for _ in range(40):
    body = independent_seq(TEST_ISA[rng.choice(specs)], RegPool(),
                           rng.randint(3, 9))
    codes.append(body * rng.randint(2, 6))
codes.append([])                       # empty sequence inside the wave

base = BatchSimMachine(SIM_SKL, TEST_ISA, backend="numpy")
ref = base.run_batch(codes)
for kind, nd in (("jax", 1), ("jax", 2), ("jax", 4), ("pallas", 4)):
    m = BatchSimMachine(SIM_SKL, TEST_ISA, backend=kind, devices=nd)
    got = m.run_batch(codes)
    assert all(a.cycles == b.cycles and a.port_uops == b.port_uops
               for a, b in zip(ref, got)), (kind, nd)
    st = m.device_stats()
    assert st["mesh"] == (nd > 1), st
    assert st["devices"] == list(range(nd)), st
    assert sum(c["lanes"] for c in st["per_device"].values()) >= 40
    c0 = st["compiles"]
    m.run_batch(codes)                 # warm wave: zero recompiles
    assert m.device_stats()["compiles"] == c0, (kind, nd)
    assert m.run_batch([]) == []

# per-device-shard crossover: 8 lanes / min_lanes 4 on 4 devices must
# clamp to a 2-device mesh (each shard keeps >= min_lanes lanes), and a
# sub-crossover chunk stays off the mesh entirely
d = m._device
assert d.mesh_width(8) == 2 and d.mesh_width(64) == 4
assert d.mesh_width(3) == 1
m2 = BatchSimMachine(SIM_SKL, TEST_ISA, backend="jax", devices=4,
                     min_lanes=4)
body = independent_seq(TEST_ISA["ADD_R64_R64"], RegPool(), 4)
thin = [body * 4] * 8                  # one 8-lane chunk, uniform length
got = m2.run_batch(thin)
assert all(a.cycles == b.cycles and a.port_uops == b.port_uops
           for a, b in zip(base.run_batch(thin), got))
widths = {k[3] for k in m2._device._rings}   # slot keys carry mesh width
assert widths == {2}, widths
print("WAVE_MESH_OK")
""")
    assert "WAVE_MESH_OK" in out


def test_characterize_xml_identical_across_device_counts():
    """characterize-to-XML is byte-identical on 1, 2 and 4 forced host
    devices and to the scalar oracle, for every SIM_UARCH."""
    out = run_py("""
from repro.core import model_io
from repro.core.characterize import characterize
from repro.core.engine import MeasurementEngine
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES

SUBSET = ["ADD_R64_R64", "ADC_R64_R64", "MUL_R64", "SHLD_R64_R64_I8",
          "MOV_M64_R64", "PADDD_X_X"]
for name in sorted(SIM_UARCHES):
    ua = SIM_UARCHES[name]
    oracle = SimMachine(ua, TEST_ISA)    # scalar/numpy reference
    want = model_io.to_xml(
        characterize(MeasurementEngine(oracle), TEST_ISA, SUBSET), TEST_ISA)
    for nd in (1, 2, 4):
        m = SimMachine(ua, TEST_ISA, backend="jax", min_lanes=1, devices=nd)
        got = model_io.to_xml(
            characterize(MeasurementEngine(m), TEST_ISA, SUBSET), TEST_ISA)
        assert got == want, (name, nd)
print("XML_MESH_OK")
""")
    assert "XML_MESH_OK" in out


def test_campaign_disjoint_device_placement():
    """Campaign.run places its machines on disjoint device subsets (each
    with its own dispatch lock) and the resulting models match a
    single-machine characterization."""
    out = run_py("""
from repro.core import model_io
from repro.core.characterize import characterize
from repro.core.engine import Campaign, MeasurementEngine
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_HSW, SIM_SKL

SUBSET = ["ADD_R64_R64", "MUL_R64", "PADDD_X_X"]
machines = [SimMachine(SIM_SKL, TEST_ISA, backend="jax", min_lanes=1),
            SimMachine(SIM_HSW, TEST_ISA, backend="jax", min_lanes=1)]
res = Campaign(instr_names=SUBSET).run(machines, TEST_ISA)
subsets = [m.device_stats()["devices"] for m in machines]
assert subsets == [[0, 1], [2, 3]], subsets
assert not (set(subsets[0]) & set(subsets[1]))
for m in machines:
    solo = SimMachine(m.uarch, TEST_ISA)
    want = model_io.to_xml(
        characterize(MeasurementEngine(solo), TEST_ISA, SUBSET), TEST_ISA)
    assert model_io.to_xml(res.models[m.name], TEST_ISA) == want, m.name
    st = res.stats[m.name]["device"]
    assert st["mesh"] is True and st["kernel_calls"] >= 1
print("CAMPAIGN_MESH_OK")
""")
    assert "CAMPAIGN_MESH_OK" in out


@needs_modern_jax
def test_moe_shard_map_matches_dense():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import load_config
from repro.models import model as MF
from repro.models.sharding import MeshAxes
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = load_config("phi3_5_moe_42b", smoke=True)
axes = MeshAxes(batch=("data",), model="model", enabled=True)
m_sh = MF.build_model(cfg, axes, mesh)
m_ref = MF.build_model(cfg)
params = m_ref.init(jax.random.PRNGKey(1))
batch = {"tokens": jnp.ones((4, 16), jnp.int32),
         "labels": jnp.ones((4, 16), jnp.int32)}
with jax.set_mesh(mesh):
    l_sh, _ = jax.jit(m_sh.loss)(params, batch)
l_ref, _ = jax.jit(m_ref.loss)(params, batch)
assert abs(float(l_sh) - float(l_ref)) < 2e-2, (l_sh, l_ref)
print("MOE_OK", float(l_sh), float(l_ref))
""")
    assert "MOE_OK" in out


@needs_modern_jax
def test_pipeline_matches_sequential():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_apply, split_stages
mesh = jax.make_mesh((4,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
L, D = 8, 16
ks = jax.random.split(jax.random.PRNGKey(0), L)
layers = {"w": jnp.stack([jax.random.normal(k, (D, D)) * 0.2 for k in ks]),
          "b": jnp.zeros((L, D))}

def apply_stack(params, x):
    def body(h, lp):
        return jnp.tanh(h @ lp["w"] + lp["b"]), None
    h, _ = jax.lax.scan(body, x, params)
    return h

xs = jax.random.normal(jax.random.PRNGKey(1), (6, 3, D))  # 6 microbatches
seq = jnp.stack([apply_stack(layers, xs[i]) for i in range(6)])
staged = split_stages(layers, 4)
with jax.set_mesh(mesh):
    out = jax.jit(lambda p, x: pipeline_apply(
        apply_stack, p, x, mesh, axis="pod"))(staged, xs)
np.testing.assert_allclose(np.asarray(out), np.asarray(seq), atol=1e-5,
                           rtol=1e-5)
print("PIPE_OK")
""")
    assert "PIPE_OK" in out


@needs_modern_jax
def test_compressed_psum_close_to_exact():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import psum_int8
mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))

def f(x):
    return psum_int8(x[0], "data"), jax.lax.psum(x[0], "data")

with jax.set_mesh(mesh):
    approx, exact = jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=P("data"), out_specs=P(),
        check_vma=False))(x)
err = float(jnp.max(jnp.abs(approx - exact)))
scale = float(jnp.max(jnp.abs(exact)))
assert err < 4 * scale / 127, (err, scale)
print("PSUM_OK", err, scale)
""")
    assert "PSUM_OK" in out


@needs_modern_jax
def test_seq_sharded_decode_matches_unsharded():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.models.layers import decode_attention
from repro.models.sharding import MeshAxes, SINGLE
from repro.configs.base import load_config
mesh = jax.make_mesh((4,), ("model",),
                     axis_types=(jax.sharding.AxisType.Auto,))
cfg = load_config("minitron_8b", smoke=True).replace(
    compute_dtype=jnp.float32)
B, S, Hq, Hkv, Dh = 2, 64, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
kc = jax.random.normal(ks[1], (B, S, Hkv, Dh))
vc = jax.random.normal(ks[2], (B, S, Hkv, Dh))
ref = decode_attention(q, kc, vc, jnp.int32(50), cfg, SINGLE)
axes = MeshAxes(batch=(), model="model", enabled=True, kv_partition="seq")
with jax.set_mesh(mesh):
    got = jax.jit(lambda *a: decode_attention(*a, cfg, axes))(
        q, kc, vc, jnp.int32(50))
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4,
                           rtol=1e-4)
print("DECODE_OK")
""")
    assert "DECODE_OK" in out


@needs_modern_jax
def test_production_mesh_shapes():
    out = run_py("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("MESH_OK", m1.axis_names, m2.axis_names)
""", devices=512)
    assert "MESH_OK" in out


@needs_modern_jax
def test_train_step_on_small_mesh():
    """Two sharded train steps on a 2x2 mesh (full jit path with
    in_shardings + donation), loss finite and decreasing-ish."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import load_config, ShapeSpec
from repro.launch.train import train
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
cfg = load_config("qwen3_8b", smoke=True)
mesh = make_host_mesh(2, 2)
shape = ShapeSpec("t", 32, 4, "train")
with jax.set_mesh(mesh):
    _, _, losses = train(cfg, shape, steps=6,
                         opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=2,
                                                   total_steps=6),
                         mesh=mesh, log_every=2, log_fn=lambda *a: None)
import math
assert all(math.isfinite(l) for _, l in losses)
print("TRAIN_MESH_OK", losses[-1][1])
""")
    assert "TRAIN_MESH_OK" in out


@needs_modern_jax
def test_vocab_parallel_ce_matches_gather():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import load_config
from repro.models import model as MF
from repro.models.sharding import MeshAxes
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg_g = load_config("qwen3_8b", smoke=True).replace(
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
cfg_v = cfg_g.replace(ce_impl="vocab_parallel", embed_sharding="model_only")
axes = MeshAxes(batch=("data",), model="model", enabled=True)
m_g = MF.build_model(cfg_g, axes, mesh)
m_v = MF.build_model(cfg_v, axes, mesh)
params = m_g.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 500),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, 500)}
with jax.set_mesh(mesh):
    lg, _ = jax.jit(m_g.loss)(params, batch)
    lv, _ = jax.jit(m_v.loss)(params, batch)
assert abs(float(lg) - float(lv)) < 1e-4, (lg, lv)
print("VP_CE_OK")
""")
    assert "VP_CE_OK" in out

"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over
shapes/dtypes as the assignment requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.microbench import BLOCKERS
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D", [
    (1, 2, 2, 64, 64, 16),
    (2, 4, 2, 128, 128, 32),   # GQA group 2
    (1, 6, 2, 96, 96, 16),     # group 3, non-pow2 seq blocks
    (2, 2, 1, 64, 128, 8),     # cross-length (prefill-style)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(B, Hq, Hkv, Sq, Sk, D, causal,
                                           dtype):
    if causal and Sq != Sk:
        pytest.skip("causal requires square layout here")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Hq, Sq, D), dtype)
    k = rand(ks[1], (B, Hkv, Sk, D), dtype)
    v = rand(ks[2], (B, Hkv, Sk, D), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
    want = ref.reference_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,h,g,p,n,chunk", [
    (1, 64, 2, 1, 16, 16, 16),
    (2, 128, 4, 2, 32, 16, 32),
    (1, 96, 2, 2, 8, 8, 16),   # uneven chunk count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_reference(b, s, h, g, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = rand(ks[3], (b, s, g, n), dtype)
    C = rand(ks[0], (b, s, g, n), dtype)
    y, st = ssd_scan(x, dt, A, B, C, chunk, interpret=True)
    y_ref, st_ref = ref.reference_ssd(x, dt, A, B, C, chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=tol, rtol=tol)


def test_ssd_chunked_equals_sequential():
    """The chunked SSD algorithm (model + kernel path) equals the plain
    recurrence — the state-space-duality identity itself."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, h, g, p, n = 2, 64, 4, 2, 8, 16
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = rand(ks[3], (b, s, g, n), jnp.float32)
    C = rand(ks[4], (b, s, g, n), jnp.float32)
    y1, st1 = ref.reference_ssd(x, dt, A, B, C, chunk=16)
    y2, st2 = ref.reference_ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("rows,d", [(32, 64), (100, 128), (7, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_reference(rows, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = rand(ks[0], (rows, d), dtype)
    w = 1 + 0.1 * rand(ks[1], (d,), jnp.float32)
    out = rmsnorm(x, w, interpret=True, block_rows=16)
    want = ref.reference_rmsnorm(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_custom_vjp_gradients():
    from repro.kernels.ops import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (1, 2, 32, 16), jnp.float32)
    k = rand(ks[1], (1, 2, 32, 16), jnp.float32)
    v = rand(ks[2], (1, 2, 32, 16), jnp.float32)

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 16, 16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.reference_attention(q, k, v, causal=True) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


@pytest.mark.parametrize("name", sorted(BLOCKERS))
def test_blocking_kernels_run(name):
    out = BLOCKERS[name](interpret=True)
    assert np.isfinite(np.asarray(out, np.float32)).all()

"""uops-as-a-service: registry, vectorized batch predictor, server.

The load-bearing guarantees:
  * predictions served from registry-loaded XML artifacts are *identical*
    to predictions from the in-memory PerfModel (round-trip + service
    path), for every simulated uarch;
  * the batched predictor agrees bit-for-bit with the single-block
    reference on randomized blocks;
  * uncharacterized instructions surface as typed / structured errors,
    never bare KeyErrors.
"""
import os
import threading

import pytest

from repro.core import model_io
from repro.core.engine import Campaign
from repro.core.isa import TEST_ISA
from repro.core.predictor import UnknownInstructionError, predict
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_UARCHES
from repro.service.batch_predictor import BatchPredictor
from repro.service.client import ServiceClient, local_service
from repro.service.protocol import (format_block, parse_block,
                                    prediction_to_dict)
from repro.service.registry import (ModelNotFoundError, ModelRegistry,
                                    StaleModelError)
from repro.service.server import (PredictionServer, PredictionService,
                                  start_server)
from repro.service.workload import random_blocks

SERVICE_NAMES = [
    "ADD_R64_R64", "IMUL_R64_R64", "MUL_R64", "ADC_R64_R64", "CMC",
    "TEST_R64_R64", "SHLD_R64_R64_I8", "MOVQ2DQ_X_X", "AESDEC_X_X",
    "PSHUFD_X_X", "PADDD_X_X", "MOV_R64_M64",
]


@pytest.fixture(scope="module")
def campaign_models():
    machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
    return Campaign(instr_names=SERVICE_NAMES).run(machines, TEST_ISA).models


@pytest.fixture(scope="module")
def model_dir(campaign_models, tmp_path_factory):
    out = tmp_path_factory.mktemp("models")
    for name, model in campaign_models.items():
        (out / f"{name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_serves_all_sim_uarches(model_dir, campaign_models):
    reg = ModelRegistry(model_dir)
    assert reg.uarches() == sorted(SIM_UARCHES)
    for name in SIM_UARCHES:
        h = reg.get(name)
        assert h.model.uarch == name
        assert h.model.fingerprint == campaign_models[name].fingerprint
        assert set(h.model.instructions) == set(
            campaign_models[name].instructions)
    # lazy: second get returns the same handle, no reload
    v = reg.get("sim_skl").version
    assert reg.get("sim_skl").version == v
    assert reg.hot_reloads == 0


def test_registry_missing_uarch(model_dir):
    reg = ModelRegistry(model_dir)
    with pytest.raises(ModelNotFoundError) as ei:
        reg.get("sim_icl")
    assert "sim_skl" in str(ei.value)


def test_registry_serves_json_artifacts(tmp_path, campaign_models):
    """JSON export is a first-class artifact: a JSON-only registry serves
    predictions identical to the in-memory model."""
    model = campaign_models["sim_skl"]
    (tmp_path / "sim_skl.json").write_text(model_io.to_json(model))
    reg = ModelRegistry(tmp_path)
    assert reg.uarches() == ["sim_skl"]
    loaded = reg.get("sim_skl").model
    assert loaded.fingerprint == model.fingerprint
    for code in random_blocks(model, TEST_ISA, 8, seed=5):
        assert predict(loaded, TEST_ISA, code) == \
            predict(model, TEST_ISA, code)
    # measurement caches in the same dir are never mistaken for models
    (tmp_path / "sim_skl.meas.json").write_text("{}")
    assert reg.uarches() == ["sim_skl"]


def test_service_errors_pickle_roundtrip():
    import pickle

    e = pickle.loads(pickle.dumps(
        ModelNotFoundError("sim_icl", ["sim_skl"])))
    assert isinstance(e, ModelNotFoundError)
    assert e.available == ["sim_skl"]
    assert "sim_icl" in str(e)
    e2 = pickle.loads(pickle.dumps(
        UnknownInstructionError(["FOO"], "sim_skl")))
    assert e2.missing == ["FOO"]
    assert str(e2) == "model sim_skl has no characterization for: FOO"


def test_registry_rejects_stale_fingerprint(model_dir):
    reg = ModelRegistry(model_dir,
                        expected_fingerprints={"sim_skl": "deadbeef"})
    with pytest.raises(StaleModelError):
        reg.get("sim_skl")
    # validation off: the same artifact loads
    reg2 = ModelRegistry(model_dir, validate=False,
                         expected_fingerprints={"sim_skl": "deadbeef"})
    assert reg2.get("sim_skl").model.uarch == "sim_skl"


def test_registry_hot_reload(model_dir, campaign_models):
    reg = ModelRegistry(model_dir)
    h1 = reg.get("sim_hsw")
    # a re-characterization campaign rewrites the artifact: drop one instr
    model = campaign_models["sim_hsw"]
    pruned = model_io.load_xml(model_io.to_xml(model, TEST_ISA))
    del pruned.instructions["CMC"]
    path = model_dir / "sim_hsw.xml"
    path.write_text(model_io.to_xml(pruned, TEST_ISA))
    os.utime(path, ns=(h1.mtime_ns + 10**9, h1.mtime_ns + 10**9))
    h2 = reg.get("sim_hsw")
    assert h2.version > h1.version
    assert "CMC" not in h2.model.instructions
    assert reg.hot_reloads == 1
    # restore for the other module-scoped tests
    path.write_text(model_io.to_xml(model, TEST_ISA))


# ---------------------------------------------------------------------------
# batch predictor vs single-block reference
# ---------------------------------------------------------------------------


def test_batch_matches_reference_bit_for_bit(campaign_models):
    for name, model in campaign_models.items():
        blocks = random_blocks(model, TEST_ISA, 30, seed=11)
        bp = BatchPredictor(model, TEST_ISA)
        batch = bp.predict_batch(blocks)
        for code, got in zip(blocks, batch):
            ref = predict(model, TEST_ISA, code)
            assert got == ref, (name, code)
            # exact float equality on every field, not approx
            assert (got.cycles, got.port_bound, got.latency_bound,
                    got.frontend_bound) == (ref.cycles, ref.port_bound,
                                            ref.latency_bound,
                                            ref.frontend_bound)


def test_batch_single_block_api(campaign_models):
    model = campaign_models["sim_skl"]
    code = [Instr("IMUL_R64_R64", {"op1": "R0", "op2": "R1"})]
    assert BatchPredictor(model, TEST_ISA).predict(code) == \
        predict(model, TEST_ISA, code)


def test_unknown_instruction_is_typed(campaign_models):
    model = campaign_models["sim_skl"]
    code = [Instr("ADD_R64_R64", {"op1": "R0", "op2": "R1"}),
            Instr("DIVPS_X_X", {"op1": "X0", "op2": "X1"}),
            Instr("SETC_R8", {"op1": "R2"})]
    with pytest.raises(UnknownInstructionError) as ei:
        predict(model, TEST_ISA, code)
    assert ei.value.missing == ["DIVPS_X_X", "SETC_R8"]
    assert ei.value.uarch == "sim_skl"
    assert isinstance(ei.value, KeyError)  # old except-clauses keep working
    # batch: on_error="return" keeps good blocks flowing
    bp = BatchPredictor(model, TEST_ISA)
    good = [Instr("CMC", {})]
    out = bp.predict_batch([code, good], on_error="return")
    assert isinstance(out[0], UnknownInstructionError)
    assert out[1] == predict(model, TEST_ISA, good)
    # characterized under a fuller ISA than we serve with: still typed
    import copy

    wider = copy.copy(model)
    wider.instructions = dict(model.instructions)
    wider.instructions["PHANTOM_OP"] = wider.instructions["CMC"]
    with pytest.raises(UnknownInstructionError) as ei:
        predict(wider, TEST_ISA, [Instr("PHANTOM_OP", {})])
    assert ei.value.missing == ["PHANTOM_OP"]


# ---------------------------------------------------------------------------
# the e2e agreement guarantee: XML round-trip + service path
# ---------------------------------------------------------------------------


def test_served_predictions_identical_to_in_memory(model_dir,
                                                   campaign_models):
    with local_service(model_dir) as client:
        assert client.uarches() == sorted(SIM_UARCHES)
        for uarch in SIM_UARCHES:
            model = campaign_models[uarch]
            blocks = random_blocks(model, TEST_ISA, 12, seed=23)
            served = client.predict_batch(uarch, blocks)
            for code, env in zip(blocks, served):
                assert env["ok"], env
                ref = prediction_to_dict(predict(model, TEST_ISA, code))
                assert env["result"] == ref, (uarch, code)


def test_service_structured_error_and_single_path(model_dir):
    with local_service(model_dir) as client:
        env = client.predict(
            "sim_skl",
            [Instr("DIV_R64", {"op1": "R0", "op2": "R1", "hi": "R2"})],
            raw=True)
        assert env["ok"] is False
        assert env["error"]["type"] == "UnknownInstructionError"
        assert env["error"]["missing"] == ["DIV_R64"]
        assert env["error"]["uarch"] == "sim_skl"
        # unknown uarch is structured too
        env = client.predict("sim_icl", "CMC", raw=True)
        assert env["ok"] is False
        assert env["error"]["type"] == "ModelNotFoundError"
        # text-format single predict works end to end
        res = client.predict("sim_skl", "IMUL_R64_R64 op1=R0 op2=R1")
        assert res["cycles"] == pytest.approx(3.0)
        assert res["bottleneck"] == "latency"
        # validate endpoint: missing specs without predicting
        assert client.validate("sim_skl", "CMC") == []
        assert client.validate(
            "sim_skl", "CMC\nDIV_R64 op1=R0 op2=R1 hi=R2") == ["DIV_R64"]


def test_service_cache_hits_and_stats(model_dir):
    with local_service(model_dir) as client:
        block = "ADD_R64_R64 op1=R0 op2=R1"
        for _ in range(5):
            client.predict("sim_skl", block)
        st = client.stats()
        assert st["cache"]["hits"] >= 4
        ep = st["endpoints"]["predict"]
        assert ep["requests"] >= 5
        assert "p50_us" in ep and "p99_us" in ep
        assert st["registry"]["loaded"].get("sim_skl")


def test_service_coalesces_queued_requests(model_dir):
    # worker not started: enqueue first, then start -> one batched pass
    service = PredictionService(ModelRegistry(model_dir), start=False,
                                batch_window_s=0.05)
    code = [Instr("CMC", {})]
    futs = [service.submit("sim_skl", code) for _ in range(10)]
    service.start()
    results = [f.result(timeout=10) for f in futs]
    service.close()
    assert all(r["ok"] for r in results)
    cs = service.coalescer.stats()
    assert cs["max_batch"] >= 2  # requests were coalesced, not serialized
    # identical requests in one wave are computed once and shared
    assert service.dedup_hits + service.cache.stats()["hits"] >= 9
    # close() -> start() must yield a live worker again
    service.start()
    assert service.submit("sim_skl", code).result(timeout=10)["ok"]
    service.close()


def test_close_resolves_pending_futures(model_dir):
    service = PredictionService(ModelRegistry(model_dir), start=False)
    futs = [service.submit("sim_skl", [Instr("CMC", {})]) for _ in range(3)]
    service.close()  # never started: futures must not be abandoned
    for f in futs:
        res = f.result(timeout=5)
        assert res["ok"] is False
        assert res["error"]["type"] == "ServiceClosed"


def test_cached_responses_are_not_aliased(model_dir):
    service = PredictionService(ModelRegistry(model_dir), start=False)
    block = [Instr("CMC", {})]
    a = service.predict_batch("sim_skl", [block])[0]
    a["result"]["cycles"] = -1.0  # caller mutates its copy...
    b = service.predict_batch("sim_skl", [block])[0]  # ...cache unharmed
    assert b["result"]["cycles"] > 0
    service.close()


def test_service_hot_reload_invalidates_cache(model_dir, campaign_models):
    reg = ModelRegistry(model_dir)
    with PredictionServer(PredictionService(reg)) as server:
        client = ServiceClient(server.host, server.port)
        before = client.predict("sim_snb", "CMC")
        # rewrite the artifact (same content, new mtime) and force reload
        path = model_dir / "sim_snb.xml"
        st = path.stat()
        path.write_text(model_io.to_xml(campaign_models["sim_snb"],
                                        TEST_ISA))
        os.utime(path, ns=(st.st_mtime_ns + 10**9, st.st_mtime_ns + 10**9))
        assert "sim_snb" in client.reload("sim_snb")
        after = client.predict("sim_snb", "CMC")
        assert after == before  # same model content => same numbers
        assert client.stats()["registry"]["hot_reloads"] >= 1
        client.close()


def test_concurrent_clients(model_dir):
    server = start_server(model_dir)
    errors = []

    def worker(seed):
        try:
            with ServiceClient(server.host, server.port) as c:
                for i in range(8):
                    res = c.predict("sim_skl",
                                    f"IMUL_R64_R64 op1=R{seed} op2=R{i}")
                    assert res["cycles"] > 0
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    server.close()
    assert not errors


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


def test_block_text_roundtrip():
    text = ("# a comment\n"
            "IMUL_R64_R64 op1=R0 op2=R1\n"
            "\n"
            "DIV_R64 op1=R0 op2=R3 hi=R4 !high\n")
    code = parse_block(text, TEST_ISA)
    assert [i.spec for i in code] == ["IMUL_R64_R64", "DIV_R64"]
    assert code[1].value_hint == "high"
    assert parse_block(format_block(code)) == code


def test_parse_block_rejects_unknown_variant():
    with pytest.raises(ValueError):
        parse_block("NOT_AN_INSTR op1=R0", TEST_ISA)


def test_format_block_round_trips_randomized_blocks():
    """Seeded analogue of the hypothesis property in test_properties.py:
    format_block is the exact inverse of parse_block, and the canonical
    text is a fixed point of another round trip."""
    import random

    from repro.core.simulator import Instr

    rng = random.Random(42)
    names = [s.name for s in TEST_ISA]
    for _ in range(50):
        code = []
        for _ in range(rng.randint(0, 8)):
            spec = rng.choice(names)
            regs = {f"op{k}": f"R{rng.randrange(16)}"
                    for k in range(rng.randint(0, 3))}
            code.append(Instr(spec, regs,
                              rng.choice(["low", "high"])))
        text = format_block(code)
        assert parse_block(text) == code
        assert format_block(parse_block(text)) == text

"""§5.1.1 blocking-instruction discovery."""
from repro.core.isa import TEST_ISA


def test_blocking_covers_ground_truth_combos(skl_blocking):
    combos = {frozenset(pc) for pc in skl_blocking.instrs}
    expected = {frozenset(x) for x in
                ("0156", "06", "01", "015", "23", "237", "4", "5", "1", "0",
                 "15")}
    assert expected <= combos


def test_blocking_instructions_are_single_uop(skl_machine, skl_blocking):
    from repro.core.machine import total_uops

    for pc, name in skl_blocking.instrs.items():
        if name == "MOV_M64_R64":  # the 2-μop store special case
            continue
        assert abs(total_uops(skl_machine, TEST_ISA[name]) - 1) < 0.1, name


def test_excluded_classes_never_selected(skl_blocking):
    banned = {"CPUID", "RDMSR", "LFENCE", "NOP", "PAUSE", "JMP_R64", "DIV_R64",
              "DIVPS_X_X"}
    assert banned.isdisjoint(set(skl_blocking.instrs.values()))


def test_throughput_selection_avoids_flag_chained(skl_blocking):
    """For p06 the candidates include flag-readers whose instances chain
    (ADC/SBB/shifts); the throughput criterion must avoid them."""
    p06 = skl_blocking.instrs[frozenset("06")]
    assert p06 in ("SETC_R8", "SAHF", "CMOVBE_R64_R64")


def test_store_ports_use_mov_special_case(skl_blocking):
    assert skl_blocking.instrs[frozenset("4")] == "MOV_M64_R64"
    assert skl_blocking.instrs[frozenset("237")] == "MOV_M64_R64"


def test_sse_avx_separate_sets(skl_machine):
    from repro.core.blocking import find_blocking_instructions

    sse = find_blocking_instructions(skl_machine, TEST_ISA,
                                     extensions=("BASE", "SSE"))
    avx = find_blocking_instructions(skl_machine, TEST_ISA,
                                     extensions=("BASE", "AVX"))
    sse_names = set(sse.instrs.values())
    avx_names = set(avx.instrs.values())
    assert not any(TEST_ISA[n].extension == "AVX" for n in sse_names)
    assert not any(TEST_ISA[n].extension == "SSE" for n in avx_names)

"""Data pipeline, optimizer, checkpointing, fault tolerance, compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (CheckpointManager, latest_checkpoint,
                                         restore_checkpoint, save_checkpoint)
from repro.configs.base import ShapeSpec, load_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim import adamw
from repro.optim.compression import (ErrorFeedback, quantize_int8,
                                     roundtrip_int8)
from repro.runtime.fault_tolerance import FleetMonitor, StragglerDetector


# ---------------------------------------------------------------------- data
def _pipe(shards=1, idx=0, batch=8):
    cfg = load_config("smollm_360m", smoke=True)
    shape = ShapeSpec("t", 32, batch, "train")
    return SyntheticTokens(cfg, shape, DataConfig(seed=3), shard_index=idx,
                           num_shards=shards)


def test_data_deterministic():
    a = _pipe().batch_at(5)
    b = _pipe().batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_labels_are_shifted_tokens():
    b = _pipe().batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_shards_partition_global_batch():
    full = _pipe(shards=1, batch=8).batch_at(2)
    parts = [_pipe(shards=4, idx=i, batch=8).batch_at(2) for i in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(full["tokens"], merged)


def test_data_steps_differ():
    p = _pipe()
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


# ------------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    target = {"w": jnp.array([1.0, 1.0]), "b": jnp.array(0.0)}
    state = adamw.init_state(params)

    def loss(p):
        return sum(jnp.sum((a - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-3)


# ------------------------------------------------------------------ checkpoint
def _tree():
    return {"w": jnp.arange(24, dtype=jnp.float32).reshape(8, 3),
            "b": jnp.ones((5,), jnp.bfloat16),
            "step_scale": jnp.float32(2.5)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t, chunks=4, metadata={"k": "v"})
    step, got, meta = restore_checkpoint(latest_checkpoint(tmp_path), t)
    assert step == 7 and meta == {"k": "v"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_elastic_reshard(tmp_path):
    """Written with 4 chunks, restored as 2-way and 8-way shards: each worker
    gets its exact slice."""
    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(16, 2)}
    save_checkpoint(tmp_path, 1, t, chunks=4)
    path = latest_checkpoint(tmp_path)
    for n in (2, 8):
        parts = [restore_checkpoint(path, t, shard_index=i, num_shards=n)[1]
                 for i in range(n)]
        merged = np.concatenate([np.asarray(p["w"]) for p in parts])
        np.testing.assert_array_equal(merged, np.asarray(t["w"]))


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, interval=2, keep=2)
    t = _tree()
    for step in range(1, 9):
        mgr.maybe_save(step, t)
    mgr.wait()
    kept = sorted(p.name for p in tmp_path.iterdir() if p.suffix == ".ckpt")
    assert len(kept) == 2
    assert kept[-1] == "00000008.ckpt"


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 3, _tree())
    assert not list(tmp_path.glob("*.tmp"))


# ------------------------------------------------------------- fault tolerance
def test_straggler_detection():
    d = StragglerDetector(alpha=1.0, threshold=2.0)
    for w in "abcd":
        d.observe(w, 1.0)
    d.observe("d", 5.0)
    assert d.stragglers() == ["d"]


def test_fleet_monitor_plans():
    now = [0.0]
    m = FleetMonitor(heartbeat_timeout=10, now_fn=lambda: now[0])
    for w in range(8):
        m.heartbeat(f"w{w}")
    assert m.plan(8, 4)["action"] == "continue"
    now[0] = 20.0
    for w in range(6):  # 2 workers dead
        m.heartbeat(f"w{w}")
    plan = m.plan(8, 4)
    assert plan["action"] == "restart_elastic"
    assert plan["new_data_parallel"] == 4
    now[0] = 40.0
    for w in range(2):
        m.heartbeat(f"w{w}")
    assert m.plan(8, 4)["action"] == "halt"


# ---------------------------------------------------------------- compression
def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    y = roundtrip_int8(x)
    err = jnp.max(jnp.abs(x - y))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_int8_quantize_shapes():
    q, s, meta = quantize_int8(jnp.ones((10, 7)))
    assert q.dtype == jnp.int8
    assert q.size % 256 == 0
    assert meta[0] == 70


def test_error_feedback_reduces_bias():
    """With EF the *accumulated* transmitted signal tracks the true sum of
    gradients far better than independent rounding."""
    rng = jax.random.PRNGKey(1)
    g_true = jax.random.normal(rng, (512,)) * 1e-4  # tiny grads: harsh case
    resid = ErrorFeedback.init(g_true)
    acc_ef = jnp.zeros_like(g_true)
    acc_naive = jnp.zeros_like(g_true)
    for _ in range(50):
        sent, resid = ErrorFeedback.apply(g_true, resid, lambda t: t)
        acc_ef += sent
        acc_naive += roundtrip_int8(g_true)
    want = 50 * g_true
    assert (float(jnp.linalg.norm(acc_ef - want)) <=
            float(jnp.linalg.norm(acc_naive - want)) + 1e-5)
    assert float(jnp.linalg.norm(acc_ef - want)) < 0.02 * float(
        jnp.linalg.norm(want)) + 1e-4


def test_train_step_loss_decreases():
    from repro.launch.train import train

    cfg = load_config("smollm_360m", smoke=True)
    shape = ShapeSpec("t", 64, 8, "train")
    _, _, losses = train(cfg, shape, steps=80,
                         opt_cfg=adamw.AdamWConfig(
                             lr=3e-3, warmup_steps=10, total_steps=80),
                         log_every=20, log_fn=lambda *a: None)
    assert losses[-1][1] < losses[0][1] - 0.05


def test_train_resume_from_checkpoint(tmp_path):
    from repro.launch.train import train

    cfg = load_config("smollm_360m", smoke=True)
    shape = ShapeSpec("t", 32, 4, "train")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20)
    # run 1: full 20 steps
    p_full, _, _ = train(cfg, shape, steps=20, opt_cfg=opt,
                         log_fn=lambda *a: None)
    # run 2: 10 steps + checkpoint, then resume to 20
    train(cfg, shape, steps=10, opt_cfg=opt, ckpt_dir=tmp_path,
          ckpt_interval=10, log_fn=lambda *a: None)
    p_res, _, _ = train(cfg, shape, steps=20, opt_cfg=opt,
                        ckpt_dir=tmp_path, ckpt_interval=100,
                        log_fn=lambda *a: None)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)


def test_generate_greedy():
    from repro.train.serve import generate

    cfg = load_config("smollm_360m", smoke=True)
    model = __import__("repro.models.model", fromlist=["build_model"]) \
        .build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.ones((2, 8), jnp.int32)
    out = generate(model, params, {"tokens": toks}, steps=4)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all()

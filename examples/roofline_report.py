"""Roofline report from the dry-run artifacts: the three terms per cell,
dominant bottleneck, and the §Perf score (ideal/bound fraction).

Run after a dry-run sweep:
  PYTHONPATH=src python -m repro.launch.dryrun --all --variant cost
  PYTHONPATH=src python examples/roofline_report.py [tag]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.roofline import full_table, markdown_table

tag = sys.argv[1] if len(sys.argv) > 1 else ""
rows = full_table(variant="cost", tag=tag)
if not rows:
    print("no cost-variant dry-run records found under experiments/dryrun")
    sys.exit(1)
print(markdown_table(rows))
worst = min(rows, key=lambda r: r["roofline_fraction"])
coll = max(rows, key=lambda r: r["collective_s"])
print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
      f"({worst['roofline_fraction']:.3f})")
print(f"most collective-bound:   {coll['arch']}/{coll['shape']} "
      f"({coll['collective_s']:.2f}s wire)")

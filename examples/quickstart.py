"""Quickstart: the uops.info pipeline end to end, in a minute.

1. Characterize a handful of instructions on the simulated Skylake-like core
   (blocking discovery → Algorithm-1 port usage → per-pair latency →
   measured + LP throughput).
2. Export the machine-readable XML (uops.info-style).
3. Predict a loop kernel with the IACA-analogue and check it against the
   machine.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import model_io
from repro.core.engine import Campaign
from repro.core.isa import TEST_ISA
from repro.core.machine import measure
from repro.core.predictor import LegacyAnalyzer, predict
from repro.core.simulator import Instr, SimMachine
from repro.core.uarch import SIM_SKL

machine = SimMachine(SIM_SKL, TEST_ISA)
names = ["ADD_R64_R64", "IMUL_R64_R64", "ADC_R64_R64", "MOVQ2DQ_X_X",
         "SHLD_R64_R64_I8", "CMC", "MOV_R64_M64", "PSHUFD_X_X"]
print(f"characterizing {len(names)} instruction variants on {machine.name}…")
campaign = Campaign(instr_names=names)
result = campaign.run([machine], TEST_ISA)
model = result.models[machine.name]
stats = result.stats[machine.name]
print(f"  {stats['executions']} unique experiments executed, "
      f"{100 * stats['hit_rate']:.0f}% of {stats['requests']} requests "
      f"served from cache/dedup")

for n in names:
    im = model[n]
    lats = {f"{s}->{d}": round(e.value, 2)
            for (s, d), e in im.latency.entries.items()}
    print(f"  {n:18s} ports={im.port_usage.notation():14s} "
          f"tp={im.throughput.measured:.2f} lat={lats}")

xml = model_io.to_xml(model, TEST_ISA)
out = Path("/tmp/quickstart_model.xml")
out.write_text(xml)
print(f"\nmachine-readable model written to {out} ({len(xml)} bytes)")

# --- predict a loop kernel and validate against the machine ---------------
loop = [Instr("IMUL_R64_R64", {"op1": "R0", "op2": "R1"}),
        Instr("ADD_R64_R64", {"op1": "R1", "op2": "R2"}),
        Instr("ADC_R64_R64", {"op1": "R3", "op2": "R0"})]
pred = predict(model, TEST_ISA, loop)
meas = measure(machine, loop)
legacy = LegacyAnalyzer(model, TEST_ISA).predict(loop)
print("\nloop kernel: IMUL r0,r1; ADD r1,r2; ADC r3,r0")
print(f"  predictor: {pred.cycles:.2f} cyc/iter (bottleneck: {pred.bottleneck})")
print(f"  machine:   {meas.cycles:.2f} cyc/iter")
print(f"  legacy(IACA-like, ignores flag deps): {legacy.cycles:.2f} cyc/iter")

"""End-to-end training driver on the synthetic pipeline with checkpointing
and straggler telemetry.

Run: PYTHONPATH=src python examples/train_100m.py           (fast demo, ~20M)
     PYTHONPATH=src python examples/train_100m.py --full    (~100M, slower)
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ShapeSpec, load_config
from repro.launch.train import train
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="~100M params")
ap.add_argument("--steps", type=int, default=150)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
args = ap.parse_args()

cfg = load_config("smollm_360m")
if args.full:
    cfg = cfg.replace(num_layers=12, d_model=768, num_heads=12,
                      num_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab_size=32000)
    shape = ShapeSpec("train100m", 512, 8, "train")
else:
    cfg = cfg.replace(num_layers=6, d_model=320, num_heads=8, num_kv_heads=4,
                      head_dim=40, d_ff=1024, vocab_size=8192)
    shape = ShapeSpec("train20m", 256, 8, "train")

print(f"training {cfg.param_count() / 1e6:.1f}M params, "
      f"batch={shape.global_batch} seq={shape.seq_len}, {args.steps} steps")
params, opt_state, losses = train(
    cfg, shape, steps=args.steps,
    opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                              total_steps=args.steps),
    ckpt_dir=args.ckpt_dir, ckpt_interval=50, microbatches=2)
print(f"loss: {losses[0][1]:.3f} -> {losses[-1][1]:.3f} "
      f"(checkpoints in {args.ckpt_dir})")
assert losses[-1][1] < losses[0][1], "loss must decrease"

"""End-to-end serving driver: batched prefill + autoregressive decode on a
~100M-parameter SmolLM-family model, with wall-clock throughput and the
perf-model's memory-roofline sanity check.

Run: PYTHONPATH=src python examples/serve_batched.py [--big]
  (default: reduced dims for a fast CPU demo; --big uses ~100M params)
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import load_config
from repro.models import model as MF
from repro.train.serve import make_serve_step

ap = argparse.ArgumentParser()
ap.add_argument("--big", action="store_true", help="~100M params")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prefill", type=int, default=128)
ap.add_argument("--decode", type=int, default=64)
args = ap.parse_args()

cfg = load_config("smollm_360m")
if args.big:  # ~100M-param variant of the family
    cfg = cfg.replace(num_layers=12, d_model=768, num_heads=12,
                      num_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab_size=32000)
else:
    cfg = cfg.replace(num_layers=6, d_model=256, num_heads=8, num_kv_heads=4,
                      head_dim=32, d_ff=768, vocab_size=8192)
model = MF.build_model(cfg)
n_params = cfg.param_count()
print(f"model: {cfg.name}-variant, {n_params / 1e6:.1f}M params, "
      f"batch={args.batch}")

params = model.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1),
                          (args.batch, args.prefill), 0, cfg.vocab_size)

prefill = jax.jit(lambda p, b: model.prefill(
    p, b, pad_to=args.prefill + args.decode))
serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

t0 = time.perf_counter()
logits, state = jax.block_until_ready(prefill(params, {"tokens": toks}))
t_prefill = time.perf_counter() - t0
print(f"prefill: {args.batch}x{args.prefill} tokens in {t_prefill:.2f}s "
      f"({args.batch * args.prefill / t_prefill:.0f} tok/s)")

tok = jnp.argmax(logits, -1).astype(jnp.int32)
# warm-up decode compile
tok, _, state = serve_step(params, state, tok, None)
t0 = time.perf_counter()
out = [tok]
for _ in range(args.decode - 1):
    tok, _, state = serve_step(params, state, tok, None)
    out.append(tok)
jax.block_until_ready(tok)
t_decode = time.perf_counter() - t0
rate = args.batch * (args.decode - 1) / t_decode
print(f"decode: {args.decode - 1} steps x{args.batch} in {t_decode:.2f}s "
      f"({rate:.0f} tok/s, {1e3 * t_decode / (args.decode - 1):.1f} ms/step)")

# perf-model sanity: decode is memory-bound; floor = param+cache bytes / bw
from repro.analysis.roofline import decode_state_bytes  # noqa: E402
from repro.configs.base import ShapeSpec  # noqa: E402

shape = ShapeSpec("serve", args.prefill + args.decode, args.batch, "decode")
floor_bytes = cfg.param_count() * 4 + decode_state_bytes(cfg, shape)
print(f"memory floor per decode step: {floor_bytes / 1e6:.1f} MB "
      f"(params + KV cache) -> the serving roofline the §Perf analysis "
      f"reasons about")
sample = jnp.stack(out, axis=1)[0, :16]
print("sample continuation token ids:", list(map(int, sample)))

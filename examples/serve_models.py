"""Start uops-as-a-service over the exported model artifacts.

Serves every uarch found under experiments/models/ (run
examples/export_models.py first) on a TCP port speaking the
newline-delimited JSON protocol. Query it with scripts/analyze.py
--connect, or programmatically with repro.service.client.ServiceClient.

Run: PYTHONPATH=src python examples/serve_models.py [--port 8642]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.service.server import start_server  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--models",
                default=str(Path(__file__).resolve().parents[1]
                            / "experiments" / "models"))
ap.add_argument("--host", default="127.0.0.1")
ap.add_argument("--port", type=int, default=8642)
ap.add_argument("--stats-every", type=float, default=30.0,
                help="print service stats every N seconds (0: never)")
args = ap.parse_args()

server = start_server(args.models, host=args.host, port=args.port)
uarches = server.service.uarches()
if not uarches:
    print(f"no model artifacts under {args.models}; run "
          f"PYTHONPATH=src python examples/export_models.py first",
          file=sys.stderr)
    server.close()
    sys.exit(1)
print(f"uops-as-a-service on {server.host}:{server.port} "
      f"serving {uarches}")
print(f"try: PYTHONPATH=src python scripts/analyze.py /tmp/block.txt "
      f"--connect {server.host}:{server.port}")
try:
    while True:
        time.sleep(args.stats_every or 3600)
        if args.stats_every:
            st = server.service.stats()
            print(f"[stats] cache={st['cache']} "
                  f"coalescer={st['coalescer']}")
except KeyboardInterrupt:
    print("\nshutting down")
finally:
    server.close()

"""Export the full machine-readable instruction models (uops.info §6.4):
characterize every supported instruction variant on each simulated
microarchitecture and write XML + JSON under experiments/models/.

Run: PYTHONPATH=src python examples/export_models.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import model_io
from repro.core.characterize import characterize
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES

out = Path(__file__).resolve().parents[1] / "experiments" / "models"
out.mkdir(parents=True, exist_ok=True)
for name, ua in SIM_UARCHES.items():
    machine = SimMachine(ua, TEST_ISA)
    model = characterize(machine, TEST_ISA)
    (out / f"{name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    (out / f"{name}.json").write_text(model_io.to_json(model))
    print(f"{name}: {len(model.instructions)} instruction variants -> "
          f"{out / name}.xml (+.json) in {model.run_seconds:.1f}s")

"""Export the full machine-readable instruction models (uops.info §6.4):
one Campaign characterizes every supported instruction variant on all
simulated microarchitectures concurrently and writes XML + JSON under
experiments/models/.

The campaign's measurement cache is persisted next to the models, so
re-running this script is incremental: a warm re-export replays every
microbenchmark from the content-addressed cache.

Run: PYTHONPATH=src python examples/export_models.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import model_io
from repro.core.engine import Campaign
from repro.core.isa import TEST_ISA
from repro.core.simulator import SimMachine
from repro.core.uarch import SIM_UARCHES

out = Path(__file__).resolve().parents[1] / "experiments" / "models"
out.mkdir(parents=True, exist_ok=True)
machines = [SimMachine(ua, TEST_ISA) for ua in SIM_UARCHES.values()]
campaign = Campaign(cache_dir=out / "cache")
result = campaign.run(machines, TEST_ISA)
for name, model in result.models.items():
    (out / f"{name}.xml").write_text(model_io.to_xml(model, TEST_ISA))
    (out / f"{name}.json").write_text(model_io.to_json(model))
    print(f"{name}: {len(model.instructions)} instruction variants -> "
          f"{out / name}.xml (+.json) in {result.uarch_seconds[name]:.1f}s "
          f"(cache hit rate {100 * result.stats[name]['hit_rate']:.1f}%)")
print(result.report())

#!/usr/bin/env python
"""uops-as-a-service CLI: predict a basic block on every characterized
microarchitecture and print a per-uarch bottleneck report.

Reads the textual block format (see repro/service/protocol.py)::

    IMUL_R64_R64 op1=R0 op2=R1
    ADD_R64_R64 op1=R0 op2=R2

Usage:
    PYTHONPATH=src python scripts/analyze.py block.txt
    echo "CMC" | PYTHONPATH=src python scripts/analyze.py -
    PYTHONPATH=src python scripts/analyze.py block.txt --uarch sim_skl
    PYTHONPATH=src python scripts/analyze.py block.txt --connect HOST:PORT

Without --connect, an in-process service is started over --models
(default: experiments/models — run examples/export_models.py first).

With --trace-report, no block or service is needed: the argument is a
trace file written by repro.obs.export (Chrome trace JSON or JSONL; see
README §Observability) and the output is the per-wave bottleneck
attribution table from repro.analysis.wave_report::

    PYTHONPATH=src python scripts/analyze.py --trace-report run.trace.json

With --corpus-report, the argument is a corpus accuracy artifact
(experiments/corpus_accuracy.json, written by
``python -m repro.corpus evaluate``) and the output is the per-uarch
MAPE / Kendall-τ / error-bucket tables from repro.corpus.score::

    PYTHONPATH=src python scripts/analyze.py --corpus-report \\
        experiments/corpus_accuracy.json
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.service.client import ServiceClient, local_service  # noqa: E402
from repro.service.protocol import format_block, parse_block  # noqa: E402

REPO = Path(__file__).resolve().parents[1]


def report(uarch: str, resp: dict) -> str:
    if not resp.get("ok"):
        err = resp.get("error", {})
        lines = [f"{uarch}: ERROR [{err.get('type')}] {err.get('message')}"]
        if err.get("missing"):
            lines.append(f"  missing variants: {', '.join(err['missing'])}")
        return "\n".join(lines)
    r = resp["result"]
    pressure = sorted(r["port_pressure"].items(), key=lambda kv: -kv[1])
    top = ", ".join(f"p{p}={v:.2f}" for p, v in pressure[:4])
    return (f"{uarch}: {r['cycles']:.2f} cycles/iter — bottleneck: "
            f"{r['bottleneck']}\n"
            f"  bounds: ports={r['port_bound']:.2f} "
            f"latency={r['latency_bound']:.2f} "
            f"frontend={r['frontend_bound']:.2f}\n"
            f"  port pressure: {top or '-'}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("block", nargs="?",
                    help="block file in the textual format, or - for stdin "
                         "(not needed with --trace-report)")
    ap.add_argument("--models", default=str(REPO / "experiments" / "models"),
                    help="model artifact directory (local mode)")
    ap.add_argument("--connect", metavar="HOST:PORT",
                    help="query a running server instead of starting one")
    ap.add_argument("--uarch", action="append",
                    help="restrict to these uarches (repeatable)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print raw JSON responses")
    ap.add_argument("--trace-report", metavar="TRACE",
                    help="summarize a repro.obs trace file (Chrome JSON or "
                         "JSONL) instead of predicting a block")
    ap.add_argument("--top-waves", type=int, default=5, metavar="K",
                    help="slowest waves to list in --trace-report "
                         "(default 5)")
    ap.add_argument("--corpus-report", metavar="ACCURACY",
                    help="render a corpus accuracy artifact "
                         "(corpus_accuracy.json) instead of predicting "
                         "a block")
    args = ap.parse_args(argv)

    if args.corpus_report:
        from repro.corpus import format_report  # noqa: PLC0415
        rep = json.loads(Path(args.corpus_report).read_text())
        if args.as_json:
            print(json.dumps(rep, sort_keys=True, indent=1))
        else:
            print(format_report(rep))
        return 0
    if args.trace_report:
        from repro.analysis.wave_report import (  # noqa: PLC0415
            format_wave_report, report_from_file)
        rep = report_from_file(args.trace_report, top=args.top_waves)
        if args.as_json:
            print(json.dumps(rep, indent=1))
        else:
            print(format_wave_report(rep))
        return 0
    if not args.block:
        ap.error("a block file is required unless --trace-report or "
                 "--corpus-report is given")

    text = (sys.stdin.read() if args.block == "-"
            else Path(args.block).read_text())
    code = parse_block(text)
    if not code:
        print("empty block", file=sys.stderr)
        return 2

    with contextlib.ExitStack() as stack:
        if args.connect:
            host, sep, port = args.connect.rpartition(":")
            if not sep or not host or not port.isdigit():
                ap.error(f"--connect expects HOST:PORT, got {args.connect!r}")
            client = stack.enter_context(ServiceClient(host, int(port)))
        else:
            client = stack.enter_context(local_service(args.models))
        uarches = args.uarch or client.uarches()
        if not uarches:
            print(f"no model artifacts under {args.models}; run "
                  f"PYTHONPATH=src python examples/export_models.py first",
                  file=sys.stderr)
            return 1
        responses = {ua: client.predict(ua, code, raw=True)
                     for ua in uarches}

    if args.as_json:
        print(json.dumps(responses, indent=1))
        return 0
    # echo the canonical textual form (format_block is the exact inverse of
    # parse_block, so this is re-parseable as-is)
    print(f"block ({len(code)} instructions):")
    for line in format_block(code).splitlines():
        print(f"  {line}")
    print()
    for ua in uarches:
        print(report(ua, responses[ua]))
    bad = sum(1 for r in responses.values() if not r.get("ok"))
    return 1 if bad == len(responses) else 0


if __name__ == "__main__":
    raise SystemExit(main())
